"""Property-based cross-backend differential harness.

One module owns the repo's numerics contracts, as *generated* properties
instead of hand-picked sweeps (the ad-hoc shape lists that used to live in
test_backend_parity.py / test_bucket_parity.py are replaced by strategies
here; those files keep pinned regression cases):

  (a) the four matmul backends agree on ``linear`` within per-backend
      tolerances — photonic_sim and photonic_pallas to f32-epilogue noise,
      qat to dequant-reassociation noise, bf16 to 8-bit quantization noise
      (correlation, not allclose);
  (b) masked-dense and gathered-top-k ViT forwards agree for every
      backend x attention backend, including photonic_pallas in interpret
      mode — the serving parity contract under generated budgets;
  (c) the fused RoI-masked flash attention (both lowerings: the Pallas
      kernel in interpret mode and the XLA twin) matches the dense
      NEG_INF-masked oracle ``kernels/ref.py::flash_attention_ref`` over
      generated shapes, masks and dtypes.

Tolerance policy (documented in README "Testing & parity"):
  float-only paths            rtol/atol 2e-5 (2e-2 for bf16 io)
  integer-photonic pairs      bitwise on accumulates, 1e-6 after dequant
  quant vs float              corr > 0.999 (8-bit noise is not allclose-able)
  masked vs gathered (w8a8)   corr > 0.995 generated budgets / 0.999 pinned
                              ladder budgets, + allclose 0.35 (the two modes
                              absmax-scale different token sets)

Runs under real hypothesis (CI) or the deterministic fallback shim
(seed container). Reproduce a CI failure locally with the printed seed:
    PYTHONPATH=src python -m pytest tests/test_differential.py -p no:randomly
Every strategy feeds jax.random.PRNGKey(seed), so a drawn example is fully
pinned by its integers.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                    # seed container
    from _hypothesis_fallback import given, settings, st

from repro.configs.base import smoke_variant
from repro.configs.opto_vit import get_config
from repro.core import backend as be
from repro.core.backend import ExecPolicy, linear, prepare_params
from repro.core.mgnet import select_topk_patches
from repro.kernels.flash_attention import (flash_attention_masked,
                                           flash_attention_masked_xla)
from repro.kernels.ref import flash_attention_ref
from repro.models.vit import (embed_patches, forward_vit_masked,
                              forward_vit_tokens, init_vit)

pytestmark = pytest.mark.slow          # CI runs this module in the slow job

N_PATCHES = 16


# --------------------------------------------------------------------------
# shared model fixtures (one smoke ViT reused across generated examples)
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def base_cfg():
    return smoke_variant(get_config("tiny")).with_(n_layers=2)


@pytest.fixture(scope="module")
def params(base_cfg):
    return init_vit(jax.random.PRNGKey(1), base_cfg, n_classes=8)


@pytest.fixture(scope="module")
def prepared(params):
    return prepare_params(params, bits=8)


@pytest.fixture(scope="module")
def images():
    return jax.random.normal(jax.random.PRNGKey(0), (2, 32, 32, 3))


def _mask_from_idx(idx, n):
    b = idx.shape[0]
    return jnp.zeros((b, n)).at[jnp.arange(b)[:, None], idx].set(1.0)


def _masked_vs_gathered(cfg, params, images, k, seed, rtol=None):
    """The serving parity property: gathered top-k logits == masked dense
    logits, to float noise on float paths / 8-bit noise on w8a8 paths."""
    scores = jax.random.normal(jax.random.PRNGKey(seed), (2, N_PATCHES))
    toks = embed_patches(params, images, cfg)
    pruned, idx = select_topk_patches(scores, toks, k)
    lg_topk, kept = forward_vit_tokens(params, pruned, cfg)
    assert kept == k
    lg_mask, _ = forward_vit_masked(params, images,
                                    _mask_from_idx(idx, N_PATCHES), cfg)
    a = np.asarray(lg_topk, np.float32)
    m = np.asarray(lg_mask, np.float32)
    if rtol is not None:
        np.testing.assert_allclose(a, m, rtol=rtol, atol=rtol)
    else:                                   # w8a8: scale sets differ
        # generated budgets include tiny k, where per-tensor activation
        # scales diverge most between the two token sets — corr > 0.995
        # here; the pinned ladder budgets hold 0.999 (test_bucket_parity)
        assert np.corrcoef(a.ravel(), m.ravel())[0, 1] > 0.995
        np.testing.assert_allclose(a, m, rtol=0.35, atol=0.35)


# --------------------------------------------------------------------------
# (a) four matmul backends on generated shapes
# --------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(1, 64), st.integers(1, 160), st.integers(1, 96),
       st.integers(0, 2 ** 31 - 1))
def test_fuzz_linear_backend_agreement(m, k, n, seed):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (m, k))
    w = jax.random.normal(kw, (k, n))
    out = {name: np.asarray(linear(x, w, policy=ExecPolicy(backend=name,
                                                           quant_bits=8,
                                                           training=False)))
           for name in ("bf16", "qat", "photonic_sim", "photonic_pallas")}
    # the two photonic executions share one integer contract
    np.testing.assert_allclose(out["photonic_sim"], out["photonic_pallas"],
                               rtol=1e-6, atol=1e-6)
    # fake-quant computes the same w8a8 product in float order
    scale = max(np.abs(out["photonic_sim"]).max(), 1e-6)
    np.testing.assert_allclose(out["qat"], out["photonic_sim"],
                               rtol=2e-4, atol=2e-4 * scale)
    # full precision agrees to 8-bit quantization noise only
    if out["bf16"].size > 1 and np.abs(out["bf16"]).max() > 1e-6:
        corr = np.corrcoef(out["bf16"].ravel(),
                           out["photonic_sim"].ravel())[0, 1]
        assert corr > 0.999, corr


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 96), st.integers(1, 200), st.integers(1, 96),
       st.integers(0, 2 ** 31 - 1))
def test_fuzz_int_accumulate_bit_identical(m, k, n, seed):
    """The generated-shape version of the pinned tiny-96 accumulate sweep."""
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    xq = jax.random.randint(kx, (m, k), -127, 128, jnp.int32).astype(jnp.int8)
    wq = jax.random.randint(kw, (k, n), -127, 128, jnp.int32).astype(jnp.int8)
    exact = np.asarray(be.int_accumulate_exact(xq, wq))
    np.testing.assert_array_equal(exact, np.asarray(be.int_accumulate_sim(xq, wq)))
    np.testing.assert_array_equal(exact,
                                  np.asarray(be.int_accumulate_pallas(xq, wq)))


# --------------------------------------------------------------------------
# (b) masked vs gathered forwards, generated budgets
# --------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(st.integers(1, N_PATCHES), st.integers(0, 2 ** 31 - 1),
       st.sampled_from(["standard", "decomposed"]),
       st.sampled_from(["", "flash"]))
def test_fuzz_masked_vs_gathered_bf16(base_cfg, params, images,
                                      k, seed, attn_impl, attn_backend):
    cfg = base_cfg.with_(matmul_backend="bf16", attn_impl=attn_impl,
                         attn_backend=attn_backend)
    _masked_vs_gathered(cfg, params, images, k, seed, rtol=1e-4)


@settings(max_examples=4, deadline=None)
@given(st.integers(1, N_PATCHES - 1), st.integers(0, 2 ** 31 - 1),
       st.sampled_from(["qat", "photonic_sim"]),
       st.sampled_from(["", "flash"]))
def test_fuzz_masked_vs_gathered_quant(base_cfg, params, prepared, images,
                                       k, seed, backend, attn_backend):
    cfg = base_cfg.with_(matmul_backend=backend, quant_bits=8,
                         attn_backend=attn_backend)
    p = prepared if backend.startswith("photonic") else params
    _masked_vs_gathered(cfg, p, images, k, seed)


@settings(max_examples=2, deadline=None)
@given(st.sampled_from([4, 8, 12]), st.integers(0, 2 ** 31 - 1),
       st.sampled_from(["", "flash"]))
def test_fuzz_masked_vs_gathered_pallas_interpret(base_cfg, prepared, images,
                                                  k, seed, attn_backend):
    """The acceptance path: the int8 Pallas kernel (interpret mode) holds
    the same masked-vs-gathered contract; with attn_backend=flash the
    whole MHSA block runs the fused prequant serving hot path."""
    cfg = base_cfg.with_(matmul_backend="photonic_pallas", quant_bits=8,
                         attn_backend=attn_backend)
    _masked_vs_gathered(cfg, prepared, images, k, seed)


# --------------------------------------------------------------------------
# (c) fused RoI-masked attention vs the dense NEG_INF oracle
# --------------------------------------------------------------------------

def _qkv_mask(seed, b, h, hk, hv, s, d, dv, density, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (b, h, s, d), dtype)
    k = jax.random.normal(ks[1], (b, hk, s, d), dtype)
    v = jax.random.normal(ks[2], (b, hv, s, dv), dtype)
    mask = (jax.random.uniform(ks[3], (b, s)) < density).astype(jnp.float32)
    mask = mask.at[:, 0].set(1.0)          # the [cls] invariant
    return q, k, v, mask


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.sampled_from([1, 2, 4]),
       st.integers(4, 48), st.sampled_from([8, 16, 32]),
       st.floats(0.1, 1.0), st.integers(0, 2 ** 31 - 1))
def test_fuzz_fused_masked_xla_twin_matches_ref(b, h, s, d, density, seed):
    q, k, v, mask = _qkv_mask(seed, b, h, h, h, s, d, d, density)
    out = flash_attention_masked_xla(q, k, v, mask)
    ref = flash_attention_ref(q, k, v, causal=False, key_mask=mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=6, deadline=None)
@given(st.integers(1, 2), st.sampled_from([(2, 1, 2), (4, 2, 4), (2, 2, 2)]),
       st.integers(4, 40), st.sampled_from([(16, 16), (32, 8)]),
       st.floats(0.15, 1.0), st.integers(0, 2 ** 31 - 1),
       st.sampled_from([16, 64]))
def test_fuzz_fused_masked_kernel_matches_ref(b, heads, s, dims, density,
                                              seed, bkv):
    """The Pallas kernel itself (interpret mode), over generated GQA/MQA
    head layouts, D != Dv, block sizes, shapes that need padding, and
    mask densities — bit-compared (allclose 2e-5) to the masked oracle."""
    h, hk, hv = heads
    d, dv = dims
    q, k, v, mask = _qkv_mask(seed, b, h, hk, hv, s, d, dv, density)
    out = flash_attention_masked(q, k, v, mask, bq=16, bkv=bkv)
    ref = flash_attention_ref(q, k, v, causal=False, key_mask=mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=6, deadline=None)
@given(st.integers(1, 2), st.integers(4, 40), st.integers(0, 40),
       st.integers(0, 2 ** 31 - 1))
def test_fuzz_fused_kvlen_matches_mask(b, s, kv_len, seed):
    """Packed kept-count == explicit prefix mask, on both lowerings."""
    kv_len = min(kv_len, s)
    q, k, v, _ = _qkv_mask(seed, b, 2, 2, 2, s, 16, 16, 1.0)
    prefix = jnp.broadcast_to(
        (jnp.arange(s) < kv_len).astype(jnp.float32)[None], (b, s))
    ref = flash_attention_ref(q, k, v, causal=False, key_mask=prefix)
    out_k = flash_attention_masked(q, k, v, kv_len=kv_len, bq=16, bkv=16)
    out_x = flash_attention_masked_xla(q, k, v, kv_len=kv_len)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(out_x), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------------
# pinned regression seeds (cases that once failed or probe known edges)
# --------------------------------------------------------------------------

PINNED = [
    # (b, (h, hk, hv), s, (d, dv), density, seed, bkv)
    (1, (2, 1, 2), 37, (64, 24), 0.5, 7, 16),    # Eq.2 layout: MQA keys, dv<d
    (2, (4, 2, 4), 17, (16, 16), 0.3, 11, 16),   # GQA + heavy pruning
    (1, (2, 2, 2), 33, (32, 32), 1.0, 3, 16),    # dense (no mask effect)
    (2, (2, 2, 2), 16, (16, 16), 0.05, 5, 8),    # near-empty mask, cls only
]


@pytest.mark.parametrize("b,heads,s,dims,density,seed,bkv", PINNED)
def test_pinned_fused_masked_kernel(b, heads, s, dims, density, seed, bkv):
    h, hk, hv = heads
    d, dv = dims
    q, k, v, mask = _qkv_mask(seed, b, h, hk, hv, s, d, dv, density)
    ref = flash_attention_ref(q, k, v, causal=False, key_mask=mask)
    out = flash_attention_masked(q, k, v, mask, bq=16, bkv=bkv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    out_x = flash_attention_masked_xla(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out_x), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_pinned_all_masked_rows_return_zero():
    """A batch row whose every key is pruned outputs exactly 0 on the
    kernel, the XLA twin, the oracle AND both attend() backends (the
    zero-denominator guard is part of the attention contract, not a
    flash-only behavior)."""
    from repro.core.backend import attend
    q, k, v, _ = _qkv_mask(0, 2, 2, 2, 2, 12, 16, 16, 1.0)
    mask = jnp.zeros((2, 12)).at[0, 3].set(1.0)    # row 1 fully masked
    for fn in (lambda: flash_attention_masked(q, k, v, mask, bq=8, bkv=8),
               lambda: flash_attention_masked_xla(q, k, v, mask),
               lambda: flash_attention_ref(q, k, v, causal=False,
                                           key_mask=mask),
               lambda: attend(q, k, v, ExecPolicy(), mask=mask),
               lambda: attend(q, k, v, ExecPolicy(attn_backend="flash"),
                              mask=mask)):
        out = np.asarray(fn())
        assert np.isfinite(out).all()
        np.testing.assert_array_equal(out[1], np.zeros_like(out[1]))


def test_pinned_fused_prequant_accepts_elided_mask(base_cfg, prepared):
    """The fused hot path accepts the same lead-dim-elided (n,) masks the
    composed dispatch broadcasts — whether cached weights are installed
    must not change the accepted mask shapes of mhsa_standard."""
    from repro.core.backend import QuantizedWeight
    from repro.core.decomposed_attention import mhsa_standard
    blk = {name: QuantizedWeight(w.wq[0], w.scale[0], w.bits)
           for name, w in prepared["blocks"]["attn"].items()}
    x = jax.random.normal(jax.random.PRNGKey(8), (2, 8, base_cfg.d_model))
    pol = ExecPolicy(backend="photonic_pallas", quant_bits=8,
                     attn_backend="flash")
    shared = jnp.zeros((8,)).at[:5].set(1.0)
    o_1d = mhsa_standard(x, blk, base_cfg.n_heads, pol, shared)
    o_2d = mhsa_standard(x, blk, base_cfg.n_heads, pol,
                         jnp.broadcast_to(shared[None], (2, 8)))
    np.testing.assert_array_equal(np.asarray(o_1d), np.asarray(o_2d))


def test_pinned_bf16_io_fused_masked():
    q, k, v, mask = _qkv_mask(9, 1, 2, 2, 2, 24, 16, 16, 0.6, jnp.bfloat16)
    out = flash_attention_masked(q, k, v, mask, bq=8, bkv=8)
    assert out.dtype == jnp.bfloat16
    ref = flash_attention_ref(q, k, v, causal=False, key_mask=mask)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("k", [4, 8, 12])
def test_pinned_one_shape_kvlen_matches_gathered(base_cfg, params, images, k):
    """One-shape serving parity: encoding all N score-ordered tokens with
    a static packed kv_len == encoding the gathered top-k tokens (the
    first k of the same order) — on both attention backends."""
    scores = jax.random.normal(jax.random.PRNGKey(3), (2, N_PATCHES))
    order = jnp.argsort(scores, axis=-1, stable=True, descending=True)
    toks = embed_patches(params, images, base_cfg)
    permuted = jnp.take_along_axis(toks, order[:, :, None], axis=1)
    for ab in ("", "flash"):
        cfg = base_cfg.with_(matmul_backend="bf16", attn_backend=ab)
        lg_one, kept = forward_vit_tokens(params, permuted, cfg, kv_len=k)
        assert kept == k
        lg_gath, _ = forward_vit_tokens(params, permuted[:, :k], cfg)
        np.testing.assert_allclose(np.asarray(lg_one), np.asarray(lg_gath),
                                   rtol=1e-4, atol=1e-4,
                                   err_msg=ab or "xla")


def test_pinned_attend_broadcastable_mask_both_backends():
    """attend() accepts lead-dim-elided masks ((Skv,) shared across the
    batch) identically on both attention backends — the dispatch must not
    change the mask contract."""
    from repro.core.backend import attend
    q, k, v, _ = _qkv_mask(6, 3, 2, 2, 2, 12, 16, 16, 1.0)
    shared = jnp.zeros((12,)).at[:7].set(1.0)      # one mask, every batch
    full = jnp.broadcast_to(shared[None], (3, 12))
    for ab in ("", "flash"):
        pol = ExecPolicy(attn_backend=ab)
        got = attend(q, k, v, pol, mask=shared)
        want = attend(q, k, v, pol, mask=full)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=ab or "xla")


def test_pinned_fused_prequant_equals_composed(base_cfg, params, prepared,
                                               images):
    """The one-jit serving hot path (int8 prequant projections + fused
    masked attention) is bit-identical to composing ``linear`` + ``attend``
    — through the full masked forward."""
    mask = (jax.random.uniform(jax.random.PRNGKey(4), (2, N_PATCHES))
            > 0.5).astype(jnp.float32)
    cfg = base_cfg.with_(matmul_backend="photonic_pallas", quant_bits=8,
                        attn_backend="flash")
    lg_fused, _ = forward_vit_masked(prepared, images, mask, cfg)
    # raw weights force the composed (non-fused) dispatch, same numbers
    lg_comp, _ = forward_vit_masked(params, images, mask, cfg)
    np.testing.assert_array_equal(np.asarray(lg_fused), np.asarray(lg_comp))
