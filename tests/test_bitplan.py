"""Mixed-precision bit plans: formats, cache contract, fallback warnings,
the sensitivity calibrator, and the per-layer energy accounting.

The bitwise fused-vs-composed / segmented-scan-vs-unrolled parity of mixed
plans lives in tests/test_differential.py section (e) (slow job); this
module is the fast-suite unit coverage of everything around it:

  * plan canonicalization (``core.bitalloc``): per-layer sequences, the
    dict form with per-tensor suffix overrides, CLI parsing, and the
    hashable ``plan_key`` that ``ExecPolicy.fingerprint()`` folds into
    jit-cache keys;
  * ``prepare_params(bit_plan=...)``: per-layer widths land on the stacked
    block weights, everything else keeps the default;
  * the stale-cache contract (``_weight_bits``): a cached width that
    disagrees with a uniform ``quant_bits`` is a hard error — never a
    silent preference — unless the divergence is deliberate
    (``quant_bits=0`` or an installed ``bit_plan``);
  * the one-warning-per-fingerprint fused-fallback telemetry;
  * ``calibrate_bit_plan`` meeting its target mean width;
  * ``scale_for_bits`` + ``StreamAccounting(layer_bits=...)``: uniform-8
    plans are bit-exact to the unscaled aggregate, lower widths reduce
    both energy and the width-sensitive latency stages (ADC wall, SRAM
    code traffic) while the optical symbol time stays put.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import smoke_variant
from repro.configs.opto_vit import get_config
from repro.core import bitalloc
from repro.core.backend import (ExecPolicy, QuantizedWeight, linear,
                                prepare_params, quantize_weight,
                                reset_fused_fallback_warnings)
from repro.core.energy import (EnergyReport, accumulate_matmuls,
                               energy_of_stats, scale_for_bits)
from repro.models import ffn as ffn_mod
from repro.models.vit import embed_patches, encode_tokens, init_vit
from repro.serving.accounting import StreamAccounting

N_LAYERS = 2


@pytest.fixture(scope="module")
def cfg():
    return smoke_variant(get_config("tiny")).with_(n_layers=N_LAYERS)


@pytest.fixture(scope="module")
def params(cfg):
    return init_vit(jax.random.PRNGKey(1), cfg, n_classes=8)


# --------------------------------------------------------------------------
# plan formats (normalize / parse / resolve / key)
# --------------------------------------------------------------------------

def test_normalize_sequence_and_empty():
    assert bitalloc.normalize_bit_plan(None, 2) is None
    assert bitalloc.normalize_bit_plan((), 2) is None
    p = bitalloc.normalize_bit_plan([8, 4], 2)
    assert p == {"default": 8, "layers": (8, 4), "tensors": {}}


def test_normalize_dict_with_tensor_overrides():
    p = bitalloc.normalize_bit_plan(
        {"layers": [8, 6], "default": 8, "attn/wq": 4, "ffn/w2": [6, 4]}, 2)
    assert p["layers"] == (8, 6)
    assert p["tensors"] == {"attn/wq": 4, "ffn/w2": (6, 4)}


def test_normalize_rejects_bad_widths_and_lengths():
    with pytest.raises(ValueError, match=r"outside the photonic"):
        bitalloc.normalize_bit_plan([8, 16], 2)
    with pytest.raises(ValueError, match=r"outside the photonic"):
        bitalloc.normalize_bit_plan([8, 1], 2)
    with pytest.raises(ValueError, match=r"3 entries for 2 layers"):
        bitalloc.normalize_bit_plan([8, 6, 4], 2)


def test_parse_cli_forms(tmp_path):
    assert bitalloc.parse_bit_plan("8,6,4,8") == (8, 6, 4, 8)
    assert bitalloc.parse_bit_plan("") is None
    assert bitalloc.parse_bit_plan('{"layers": [8, 4]}') == {"layers": [8, 4]}
    f = tmp_path / "plan.json"
    f.write_text('{"layers": [6, 6], "attn/wq": 4}')
    assert bitalloc.parse_bit_plan(str(f)) == {"layers": [6, 6],
                                               "attn/wq": 4}


def test_resolve_bits_precedence():
    p = bitalloc.normalize_bit_plan(
        {"layers": [8, 6], "attn/wq": 4, "wq": 5}, 2)
    # longest matching suffix wins over the shorter one
    assert bitalloc.resolve_bits(p, ("blocks", "attn", "wq")) == 4
    assert bitalloc.resolve_bits(p, ("blocks", "mgnet", "wq")) == 5
    # block weights without an override take the per-layer assignment
    assert bitalloc.resolve_bits(p, ("blocks", "ffn", "w1")) == (8, 6)
    # everything outside the blocks subtree stays at the default
    assert bitalloc.resolve_bits(p, ("head",)) == 8
    assert bitalloc.resolve_bits(None, ("head",)) is None


def test_plan_key_hashable_and_canonical():
    a = bitalloc.plan_key(bitalloc.normalize_bit_plan(
        {"layers": [8, 4], "attn/wq": 6, "ffn/w2": 4}, 2))
    b = bitalloc.plan_key(bitalloc.normalize_bit_plan(
        {"ffn/w2": 4, "attn/wq": 6, "layers": (8, 4)}, 2))
    assert a == b and hash(a) == hash(b)
    assert bitalloc.plan_key(None) is None


def test_plan_layer_bits_and_mean():
    p = bitalloc.normalize_bit_plan([8, 4], 2)
    assert bitalloc.plan_layer_bits(p, 2) == (8, 4)
    assert bitalloc.plan_mean_bits(p, 2) == 6.0
    assert bitalloc.plan_layer_bits(None, 3) == (8, 8, 8)
    d = bitalloc.normalize_bit_plan({"default": 6}, 2)
    assert bitalloc.plan_layer_bits(d, 2) == (6, 6)


def test_fingerprint_carries_bit_plan(cfg):
    a = ExecPolicy(backend="photonic_pallas", quant_bits=8)
    b = ExecPolicy(backend="photonic_pallas", quant_bits=8,
                   bit_plan=(8, 4))
    assert a.fingerprint() != b.fingerprint()
    c = ExecPolicy.from_cfg(cfg.with_(bit_plan=(8, 4)))
    assert c.bit_plan == (8, 4)


# --------------------------------------------------------------------------
# prepare_params under a plan
# --------------------------------------------------------------------------

def test_prepare_params_applies_per_layer_widths(params):
    prep = prepare_params(params, bits=8, bit_plan=(8, 4))
    w1 = prep["blocks"]["ffn"]["w1"]
    assert isinstance(w1, QuantizedWeight) and w1.bits == (8, 4)
    assert w1.layer_bits(0) == 8 and w1.layer_bits(1) == 4
    assert w1.uniform_bits() is None
    # non-block weights stay at the default width
    assert prep["head"].bits == 8


def test_prepare_params_tensor_override(params):
    prep = prepare_params(params, bits=8,
                          bit_plan={"layers": [8, 8], "ffn/w2": 4})
    assert prep["blocks"]["ffn"]["w2"].bits == 4
    assert prep["blocks"]["ffn"]["w1"].bits == 8


def test_prepare_params_uniform_plan_collapses(params):
    prep = prepare_params(params, bits=8, bit_plan=(6, 6))
    assert prep["blocks"]["ffn"]["w1"].bits == 6      # int, not (6, 6)


def test_quantize_weight_per_layer_roundtrip():
    w = jnp.stack([jnp.eye(4), 2 * jnp.eye(4)])
    qw = quantize_weight(w, bits=(8, 4))
    assert qw.bits == (8, 4)
    for i, rtol in ((0, 1e-2), (1, 2e-1)):            # 4-bit is coarse
        sliced = QuantizedWeight(qw.wq[i], qw.scale[i], qw.layer_bits(i))
        np.testing.assert_allclose(np.asarray(sliced.dequantize()),
                                   np.asarray(w[i]), rtol=rtol, atol=rtol)
    with pytest.raises(ValueError):
        quantize_weight(jnp.eye(4), bits=(8, 4))      # 2-D vs per-layer


# --------------------------------------------------------------------------
# the stale-cache contract (_weight_bits)
# --------------------------------------------------------------------------

def test_cache_policy_mismatch_raises():
    w = quantize_weight(jax.random.normal(jax.random.PRNGKey(0), (16, 16)),
                        bits=4)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16))
    p8 = ExecPolicy(backend="photonic_pallas", quant_bits=8, training=False)
    with pytest.raises(ValueError, match=r"disagrees with"):
        linear(x, w, policy=p8)
    # deliberate divergence: defer to the cache ...
    p0 = ExecPolicy(backend="photonic_pallas", quant_bits=0, training=False)
    out = linear(x, w, policy=p0)
    assert np.isfinite(np.asarray(out)).all()
    # ... or declare the plan on the policy
    pp = ExecPolicy(backend="photonic_pallas", quant_bits=8, training=False,
                    bit_plan=(4,))
    np.testing.assert_array_equal(np.asarray(linear(x, w, policy=pp)),
                                  np.asarray(out))


def test_stacked_mixed_weight_in_2d_dispatch_raises():
    w = quantize_weight(
        jax.random.normal(jax.random.PRNGKey(0), (2, 16, 16)), bits=(8, 4))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16))
    p = ExecPolicy(backend="photonic_pallas", quant_bits=0, training=False)
    with pytest.raises(ValueError, match=r"slice it"):
        linear(x, w, policy=p)


# --------------------------------------------------------------------------
# fused-fallback warnings: once per fingerprint, silent when fused
# --------------------------------------------------------------------------

def _mlp(seed=0, d=16, dff=32, cache=True):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    p = {"w1": jax.random.normal(ks[0], (d, dff)) * 0.1,
         "b1": jax.random.normal(ks[1], (dff,)) * 0.1,
         "w2": jax.random.normal(ks[2], (dff, d)) * 0.1,
         "b2": jax.random.normal(ks[3], (d,)) * 0.1}
    if cache:
        p["w1"], p["w2"] = quantize_weight(p["w1"]), quantize_weight(p["w2"])
    return p


def test_ffn_fallback_warns_once_and_names_reason():
    reset_fused_fallback_warnings()
    p = _mlp(cache=False)                     # raw weights: ineligible
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 5, 16))
    pol = ExecPolicy(backend="photonic_pallas", quant_bits=8,
                     training=False, ffn_backend="fused")
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        ffn_mod.mlp(p, x, pol)
        ffn_mod.mlp(p, x, pol)                # second call: already warned
    msgs = [str(w.message) for w in rec
            if "fell back to composed" in str(w.message)]
    assert len(msgs) == 1
    assert "not quantize-once cached" in msgs[0]
    assert "Fused-path eligibility" in msgs[0]
    # a different fingerprint (new plan) warns again
    with warnings.catch_warnings(record=True) as rec2:
        warnings.simplefilter("always")
        ffn_mod.mlp(p, x, ExecPolicy(backend="photonic_pallas",
                                     quant_bits=8, training=False,
                                     ffn_backend="fused", bit_plan=(4,)))
    assert sum("fell back" in str(w.message) for w in rec2) == 1


def test_fused_path_is_silent(cfg, params):
    reset_fused_fallback_warnings()
    prep = prepare_params(params, bits=8, bit_plan=(8, 4))
    c = cfg.with_(matmul_backend="photonic_pallas", quant_bits=8,
                  attn_backend="flash", ffn_backend="fused", bit_plan=(8, 4))
    toks = embed_patches(prep, jax.random.normal(jax.random.PRNGKey(0),
                                                 (2, 32, 32, 3)), c)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        encode_tokens(prep, toks, c)
    assert not [w for w in rec if "fell back" in str(w.message)]


def test_full_fallback_warns_each_component_once(cfg, params):
    """Raw weights + the full fused triple requested: encoder, attention
    prequant and FFN each report their own fallback exactly once."""
    reset_fused_fallback_warnings()
    c = cfg.with_(matmul_backend="photonic_pallas", quant_bits=8,
                  attn_backend="flash", ffn_backend="fused")
    toks = embed_patches(params, jax.random.normal(jax.random.PRNGKey(0),
                                                   (2, 32, 32, 3)), c)
    pol = ExecPolicy.from_cfg(c, training=False)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        encode_tokens(params, toks, c, pol)
        encode_tokens(params, toks, c, pol)
    msgs = [str(w.message) for w in rec if "fell back" in str(w.message)]
    assert len(msgs) == 3
    assert sum("fused encoder" in m for m in msgs) == 1
    assert sum("fused attention-prequant" in m for m in msgs) == 1
    assert sum("fused FFN" in m for m in msgs) == 1


# --------------------------------------------------------------------------
# the calibrator
# --------------------------------------------------------------------------

def test_calibrator_meets_target_mean(cfg, params):
    toks = embed_patches(prepare_params(params, bits=8),
                         jax.random.normal(jax.random.PRNGKey(3),
                                           (4, 32, 32, 3)), cfg)
    pol = ExecPolicy(backend="photonic_pallas", quant_bits=8,
                     training=False)
    plan = bitalloc.calibrate_bit_plan(params, toks, cfg, pol,
                                       target_mean_bits=7.0)
    assert len(plan) == cfg.n_layers
    assert sum(plan) / len(plan) <= 7.0
    assert all(b in (8, 6, 4) for b in plan)
    # a target at (or above) the default is the uniform plan
    assert bitalloc.calibrate_bit_plan(params, toks, cfg, pol,
                                       target_mean_bits=8.0) == (8, 8)


def test_calibrator_floor_terminates(cfg, params):
    toks = embed_patches(prepare_params(params, bits=8),
                         jax.random.normal(jax.random.PRNGKey(3),
                                           (2, 32, 32, 3)), cfg)
    pol = ExecPolicy(backend="photonic_pallas", quant_bits=8,
                     training=False)
    # unreachable target: every layer bottoms out at the lowest candidate
    plan = bitalloc.calibrate_bit_plan(params, toks, cfg, pol,
                                       target_mean_bits=1.0,
                                       candidates=(6,))
    assert plan == (6, 6)


# --------------------------------------------------------------------------
# per-layer energy accounting
# --------------------------------------------------------------------------

def test_scale_for_bits_rules():
    stats, _ = accumulate_matmuls([(16, 64, 64)])
    rep = energy_of_stats(stats, nonlin_elems=100)
    rep.optical_us = 1.0
    rep.memory_us = 1.0
    half = scale_for_bits(rep, 4)
    for f in ("tuning_uj", "adc_uj", "dac_uj", "memory_uj", "memory_us"):
        assert getattr(half, f) == pytest.approx(getattr(rep, f) / 2)
    # optical_us mixes width-scaled ADC time with width-independent symbol
    # cycles, so scale_for_bits leaves it alone — width-aware optical
    # latency comes from latency_of_stats(bits=...)
    for f in ("vcsel_uj", "bpd_uj", "epu_uj", "optical_us"):
        assert getattr(half, f) == getattr(rep, f)
    same = scale_for_bits(rep, 8)
    assert same.total_uj == pytest.approx(rep.total_uj)


def test_accounting_uniform8_plan_matches_unplanned(cfg):
    a = StreamAccounting(cfg)
    b = StreamAccounting(cfg, layer_bits=(8,) * cfg.n_layers)
    for acct in (a, b):
        acct.add_encode(16, 8)
        acct.add_mgnet(2)
    assert b.mean_frame.total_uj == pytest.approx(a.mean_frame.total_uj,
                                                  rel=1e-9)
    assert b.mean_frame.total_us == pytest.approx(a.mean_frame.total_us)


def test_accounting_mixed_plan_cuts_energy_and_latency(cfg):
    uni = StreamAccounting(cfg)
    mix = StreamAccounting(cfg, layer_bits=(8, 4))
    for acct in (uni, mix):
        acct.add_encode(16, 8)
    assert mix.mean_frame.total_uj < uni.mean_frame.total_uj
    # width-aware latency: the 4-bit layer's ADC wall and SRAM code
    # traffic shrink, so modeled wall time drops below uniform-8 too
    assert mix.mean_frame.total_us < uni.mean_frame.total_us
    assert mix.mean_frame.total_us > 0.5 * uni.mean_frame.total_us
    assert mix.kfps_per_watt > uni.kfps_per_watt


def test_accounting_rejects_wrong_plan_length(cfg):
    with pytest.raises(ValueError, match="entries for"):
        StreamAccounting(cfg, layer_bits=(8,) * (cfg.n_layers + 1))
