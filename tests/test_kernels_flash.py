"""Pallas flash-attention kernels vs the shared dense oracle
(kernels/ref.py::flash_attention_ref, interpret mode) — causal/local and
the RoI-masked serving variant. Generated-shape coverage of the masked
kernel lives in tests/test_differential.py; these are the pinned cases."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import (flash_attention,
                                           flash_attention_masked,
                                           flash_attention_masked_xla)
from repro.kernels.ops import fused_attention
from repro.kernels.ref import flash_attention_ref

pytestmark = pytest.mark.slow      # interpret-mode kernels -> CI slow job


def _qkv(key, b, h, hkv, sq, skv, d, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (b, h, sq, d), dtype)
    k = jax.random.normal(k2, (b, hkv, skv, d), dtype)
    v = jax.random.normal(k3, (b, hkv, skv, d), dtype)
    return q, k, v


@pytest.mark.parametrize("b,h,hkv,s,d", [
    (1, 4, 4, 128, 32),       # MHA
    (2, 4, 2, 128, 32),       # GQA 2x
    (1, 8, 1, 256, 16),       # MQA
])
def test_causal_matches_ref(b, h, hkv, s, d):
    q, k, v = _qkv(jax.random.PRNGKey(0), b, h, hkv, s, s, d)
    out = flash_attention(q, k, v, causal=True, bq=64, bkv=64)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [16, 64])
def test_local_window(window):
    q, k, v = _qkv(jax.random.PRNGKey(1), 1, 2, 2, 128, 128, 16)
    out = flash_attention(q, k, v, causal=True, window=window, bq=32,
                          bkv=32)
    ref = flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_non_causal():
    q, k, v = _qkv(jax.random.PRNGKey(2), 1, 2, 2, 64, 128, 32)
    out = flash_attention(q, k, v, causal=False, bq=32, bkv=64)
    ref = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("bq,bkv", [(32, 32), (64, 128), (128, 64)])
def test_block_shape_invariance(bq, bkv):
    q, k, v = _qkv(jax.random.PRNGKey(3), 1, 4, 2, 128, 128, 32)
    out = flash_attention(q, k, v, causal=True, bq=bq, bkv=bkv)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_bf16_io():
    q, k, v = _qkv(jax.random.PRNGKey(4), 1, 2, 2, 64, 64, 32, jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True, bq=32, bkv=32)
    ref = flash_attention_ref(q, k, v, causal=True)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-2, atol=2e-2)


# --------------------------------------------------------------------------
# RoI-masked variant (key keep-mask / packed kept-count)
# --------------------------------------------------------------------------

def _masked_setup(seed=0, b=2, h=4, s=37, d=32, density=0.5):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(k1, (b, h, s, d))
    k = jax.random.normal(k2, (b, h, s, d))
    v = jax.random.normal(k3, (b, h, s, d))
    mask = (jax.random.uniform(k4, (b, s)) < density
            ).astype(jnp.float32).at[:, 0].set(1.0)
    return q, k, v, mask


def test_masked_matches_ref():
    q, k, v, mask = _masked_setup()
    out = flash_attention_masked(q, k, v, mask, bq=16, bkv=16)
    ref = flash_attention_ref(q, k, v, causal=False, key_mask=mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("bq,bkv", [(8, 8), (16, 32), (64, 16), (128, 128)])
def test_masked_block_shape_invariance(bq, bkv):
    """Block tiling (and therefore which KV blocks get skipped) must not
    change the numbers — the streaming-softmax merge is exact."""
    q, k, v, mask = _masked_setup(seed=1, s=48, density=0.3)
    ref = flash_attention_ref(q, k, v, causal=False, key_mask=mask)
    out = flash_attention_masked(q, k, v, mask, bq=bq, bkv=bkv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_masked_kernel_agrees_with_xla_lowering():
    """The two lowerings of fused_masked_attention (Pallas kernel vs the
    CPU-host XLA twin) implement one contract."""
    q, k, v, mask = _masked_setup(seed=2, s=24, density=0.4)
    a = flash_attention_masked(q, k, v, mask, bq=8, bkv=8)
    b = flash_attention_masked_xla(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)


def test_masked_kvlen_packed_skip():
    """Packed kept-count: keys >= kv_len contribute nothing, and changing
    their values must not change the output (they are never computed)."""
    q, k, v, _ = _masked_setup(seed=3, s=32)
    out = flash_attention_masked(q, k, v, kv_len=9, bq=8, bkv=8)
    # poison the dead tail: a skipped block must never read it
    k_poison = k.at[:, :, 16:].set(1e4)
    v_poison = v.at[:, :, 16:].set(-1e4)
    out_p = flash_attention_masked(q, k_poison, v_poison, kv_len=9,
                                   bq=8, bkv=8)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out_p))
    prefix = jnp.broadcast_to((jnp.arange(32) < 9).astype(jnp.float32)[None],
                              (2, 32))
    ref = flash_attention_ref(q, k, v, causal=False, key_mask=prefix)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_fused_roi_attention_prequant_matches_float_composition():
    """ops.fused_roi_attention_prequant (int8 cached projections + fused
    masked attention) == quantize-dequant projections + the dense oracle,
    to f32 epilogue noise."""
    from repro.core.backend import quantize_weight
    from repro.kernels.ops import fused_roi_attention_prequant

    b, n, dm, heads = 2, 17, 32, 4
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    x = jax.random.normal(ks[0], (b, n, dm))
    ws = [jax.random.normal(kk, (dm, dm)) for kk in ks[1:4]]
    mask = (jax.random.uniform(ks[4], (b, n)) < 0.6
            ).astype(jnp.float32).at[:, 0].set(1.0)
    qws = [quantize_weight(w) for w in ws]
    out = fused_roi_attention_prequant(
        x, qws[0].wq, qws[0].scale.reshape(-1),
        qws[1].wq, qws[1].scale.reshape(-1),
        qws[2].wq, qws[2].scale.reshape(-1), mask, heads=heads)

    from repro.core.backend import ExecPolicy, linear
    pol = ExecPolicy(backend="photonic_pallas", quant_bits=8)
    proj = [linear(x, qw, policy=pol) for qw in qws]
    split = [p.reshape(b, n, heads, dm // heads).transpose(0, 2, 1, 3)
             for p in proj]
    ref = flash_attention_ref(*split, causal=False, key_mask=mask)
    ref = ref.transpose(0, 2, 1, 3).reshape(b, n, dm)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_fused_attention_models_layout():
    """(B, S, H, D) wrapper == models/attention layout oracle."""
    from repro.models.attention import full_attention
    key = jax.random.PRNGKey(5)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (2, 128, 4, 32))
    k = jax.random.normal(k2, (2, 128, 2, 32))
    v = jax.random.normal(k3, (2, 128, 2, 32))
    out = fused_attention(q, k, v, causal=True, bq=64, bkv=64)
    ref = full_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
