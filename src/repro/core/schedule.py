"""Five-core pipelined schedule model (paper Fig. 5).

The optical block has 5 cores (C1..C5). With the Eq. 2 decomposition, the
attention step for one input needs these MatMuls:

    C1: Q      = X @ W_Q             (tunable at t0: W_Q)
    C2: QWk    = Q @ (W_K^T/sqrt dk) (tunable at t0: W_K^T)
    C3: S      = QWk @ X^T           (tunable at t0: X^T)
    -- softmax in the EPU --
    C4: A      = softmax(S) @ ...    (tuned while C1-C3 compute)
    C5: out    = A @ W_V ...         (tuned while C1-C3 compute)

Without the decomposition, computing S = Q K^T requires K to exist before a
core can be tuned with K^T: one extra serialized tuning + a K buffer.

This module provides a small event-driven occupancy simulator for both
schedules so benchmarks can report the pipeline utilization / latency delta
attributable to the decomposition (the paper's Fig. 5 argument), without
pretending to cycle accuracy.
"""

from __future__ import annotations

from dataclasses import dataclass, field

__all__ = ["CoreTask", "simulate_pipeline", "attention_schedule"]


@dataclass
class CoreTask:
    name: str
    core: int                 # 0..n_cores-1
    compute_us: float         # optical compute duration
    tuning_us: float          # MR tuning before compute can start
    deps: tuple[str, ...] = ()  # task names that must finish first
    # tuning can begin once `tune_deps` are done (operand availability);
    # by default tuning needs no deps (operand known at t0) — that is the
    # decomposition's win.
    tune_deps: tuple[str, ...] = ()


def simulate_pipeline(tasks: list[CoreTask], n_cores: int = 5,
                      epu_tasks: dict[str, tuple[float, tuple[str, ...]]] | None = None):
    """Greedy list-scheduler over cores; returns (makespan_us, timeline).

    epu_tasks: name -> (duration_us, deps) executed on the electronic unit
    (assumed unlimited parallelism vs the 5 scarce optical cores).
    """
    epu_tasks = epu_tasks or {}
    finish: dict[str, float] = {}
    core_free = [0.0] * n_cores
    timeline = []
    pending = list(tasks)
    epu_pending = dict(epu_tasks)

    def ready(deps):
        return all(d in finish for d in deps)

    progress = True
    while (pending or epu_pending) and progress:
        progress = False
        for name, (dur, deps) in list(epu_pending.items()):
            if ready(deps):
                start = max((finish[d] for d in deps), default=0.0)
                finish[name] = start + dur
                timeline.append((name, "EPU", start, finish[name]))
                del epu_pending[name]
                progress = True
        for t in list(pending):
            if ready(t.deps) and ready(t.tune_deps):
                tune_start = max([core_free[t.core]] +
                                 [finish[d] for d in t.tune_deps])
                compute_start = max([tune_start + t.tuning_us] +
                                    [finish[d] for d in t.deps])
                finish[t.name] = compute_start + t.compute_us
                core_free[t.core] = finish[t.name]
                timeline.append((t.name, f"C{t.core + 1}", tune_start, finish[t.name]))
                pending.remove(t)
                progress = True
    if pending or epu_pending:
        raise ValueError(f"deadlock: unresolved {pending} / {epu_pending}")
    return max(finish.values()), sorted(timeline, key=lambda r: r[2])


def attention_schedule(compute_us: float, tuning_us: float, softmax_us: float,
                       decomposed: bool = True):
    """Build the Fig. 5 attention-head task graph for one input.

    Returns (makespan, timeline). ``decomposed=False`` models the naive
    Q.K^T flow where the score core's tuning must wait for K (tune_deps).
    """
    if decomposed:
        tasks = [
            CoreTask("Q", 0, compute_us, tuning_us),
            CoreTask("QWk", 1, compute_us, tuning_us, deps=("Q",)),
            CoreTask("S", 2, compute_us, tuning_us, deps=("QWk",)),
            CoreTask("AV", 3, compute_us, tuning_us, deps=("softmax",),
                     tune_deps=()),          # W_V tunable at t0
            CoreTask("proj", 4, compute_us, tuning_us, deps=("AV",)),
        ]
    else:
        tasks = [
            CoreTask("Q", 0, compute_us, tuning_us),
            CoreTask("K", 1, compute_us, tuning_us),
            # K^T must be tuned AFTER K exists -> serialized tuning bubble.
            CoreTask("S", 2, compute_us, tuning_us, deps=("Q",),
                     tune_deps=("K",)),
            CoreTask("AV", 3, compute_us, tuning_us, deps=("softmax",)),
            CoreTask("proj", 4, compute_us, tuning_us, deps=("AV",)),
        ]
    epu = {"softmax": (softmax_us, ("S",))}
    return simulate_pipeline(tasks, n_cores=5, epu_tasks=epu)
