"""MoE routing/dispatch tests (sort-based capacity dispatch, GShard-style)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import _combine_group, _dispatch_group, init_moe, moe_ffn


def test_dispatch_combine_identity():
    """With identity experts and ample capacity, combine(dispatch(x)) == x
    (gates normalized to sum 1 per token)."""
    t, d, e, k = 16, 8, 4, 2
    x = jax.random.normal(jax.random.PRNGKey(0), (t, d))
    probs = jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(1), (t, e)), -1)
    cap = t * k                                   # no drops possible
    disp, info = _dispatch_group(x, probs, k, cap)
    y = _combine_group(disp, info, t, k, x.dtype)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x),
                               rtol=1e-5, atol=1e-5)


def test_capacity_drops_zero_contribution():
    """cap=1: each expert processes at most one slot; dropped tokens
    contribute zero (GShard over-capacity semantics)."""
    t, d, e, k = 8, 4, 2, 1
    x = jnp.ones((t, d))
    probs = jnp.tile(jnp.asarray([[0.9, 0.1]]), (t, 1))   # all want expert 0
    disp, info = _dispatch_group(x, probs, k, cap=1)
    y = _combine_group(disp, info, t, k, x.dtype)
    kept_rows = int((np.abs(np.asarray(y)).sum(-1) > 0).sum())
    assert kept_rows == 1                                  # only one survived


def test_moe_ffn_shapes_and_aux():
    p = init_moe(jax.random.PRNGKey(0), 16, 32, 8, shared_experts=1,
                 dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    y, aux = moe_ffn(p, x, top_k=2, capacity_factor=2.0)
    assert y.shape == x.shape
    assert not bool(jnp.isnan(y).any())
    # Switch aux loss: e * sum(me * load) ~= 1 for uniform routing, >= 1 else
    assert 0.5 < float(aux) < 8.0


def test_moe_groups_consistency():
    """Group count changes dispatch locality, not semantics: with ample
    capacity the outputs must agree across group counts."""
    p = init_moe(jax.random.PRNGKey(0), 16, 32, 4, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 16))
    y1, _ = moe_ffn(p, x, top_k=2, capacity_factor=8.0, groups=1)
    y2, _ = moe_ffn(p, x, top_k=2, capacity_factor=8.0, groups=4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)


def test_shard_map_path_matches_gspmd():
    """Explicit-EP shard_map MoE == GSPMD MoE on the host mesh (the
    256-chip equivalence is structural: same math, manual collectives)."""
    import numpy as np
    from repro.distributed.sharding import use_sharding
    from repro.launch.mesh import make_host_mesh
    from repro.models.moe import moe_ffn_shard_map
    p = init_moe(jax.random.PRNGKey(0), 16, 32, 8, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 16))
    mesh = make_host_mesh(1, 1)
    with mesh, use_sharding(mesh):
        y1, a1 = moe_ffn(p, x, top_k=2, capacity_factor=2.0, groups=1)
        y2, a2 = jax.jit(lambda p, x: moe_ffn_shard_map(
            p, x, top_k=2, capacity_factor=2.0))(p, x)
        g = jax.grad(lambda p: moe_ffn_shard_map(p, x, top_k=2)[0].sum())(p)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-5)
    assert float(a1) == pytest.approx(float(a2), rel=1e-5)
    gn = sum(float(jnp.abs(l).sum()) for l in jax.tree_util.tree_leaves(g))
    assert np.isfinite(gn) and gn > 0


def test_shard_map_falls_back_without_ctx():
    from repro.models.moe import moe_ffn_shard_map
    p = init_moe(jax.random.PRNGKey(0), 8, 16, 4, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 8))
    y, aux = moe_ffn_shard_map(p, x, top_k=2)     # no mesh installed
    assert y.shape == x.shape


def test_moe_grad_flows():
    p = init_moe(jax.random.PRNGKey(0), 8, 16, 4, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 8))

    def loss(p):
        y, aux = moe_ffn(p, x, top_k=2)
        return (y ** 2).mean() + 0.01 * aux

    g = jax.grad(loss)(p)
    gn = sum(float(jnp.abs(l).sum()) for l in jax.tree_util.tree_leaves(g))
    assert np.isfinite(gn) and gn > 0
