"""Quantization unit + property tests (paper §IV Accuracy Analysis)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:    # seed container: deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import quant


class TestQuantRange:
    def test_8bit_symmetric(self):
        assert quant.quant_range(8) == (-127, 127)

    def test_4bit(self):
        assert quant.quant_range(4) == (-7, 7)

    def test_rejects_1bit(self):
        with pytest.raises(ValueError):
            quant.quant_range(1)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(-1e3, 1e3, allow_nan=False, width=32),
                min_size=2, max_size=64),
       st.sampled_from([4, 6, 8]))
def test_roundtrip_error_bound(vals, bits):
    """|fq(x) - x| <= scale/2 for in-range values (uniform quantizer)."""
    x = jnp.asarray(vals, jnp.float32)
    scale = quant.absmax_scale(x, bits=bits)
    y = quant.fake_quant(x, bits=bits)
    assert float(jnp.max(jnp.abs(y - x))) <= float(scale) / 2 + 1e-6


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_symmetry(seed):
    """Symmetric quantization: fq(-x) == -fq(x)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (32,))
    a = quant.fake_quant(x, bits=8)
    b = quant.fake_quant(-x, bits=8)
    np.testing.assert_allclose(np.asarray(a), -np.asarray(b), atol=1e-7)


def test_per_channel_scale_shape():
    w = jnp.ones((16, 8))
    s = quant.absmax_scale(w, bits=8, axis=0)
    assert s.shape == (1, 8)


def test_quantize_dtype():
    x = jnp.linspace(-1, 1, 16)
    s = quant.absmax_scale(x, bits=8)
    q = quant.quantize(x, s, bits=8)
    assert q.dtype == jnp.int8
    assert int(jnp.max(jnp.abs(q.astype(jnp.int32)))) <= 127


def test_ste_gradient_passthrough():
    """d fake_quant / dx == 1 strictly inside the clip range (the absmax
    element sits exactly on the boundary where clip's subgradient is
    implementation-defined — skip it)."""
    def f(x):
        return quant.fake_quant_ste(x, bits=8).sum()

    x = jnp.array([0.1, -0.5, 0.3, 1.0])    # absmax = 1.0 (boundary elem)
    g = jax.grad(f)(x)
    np.testing.assert_allclose(np.asarray(g[:3]), 1.0, atol=1e-6)


def test_ste_training_reduces_loss():
    """A linear model trained *through* fake-quant converges (QAT works)."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (128, 8))
    w_true = jax.random.normal(jax.random.PRNGKey(1), (8, 1))
    y = x @ w_true

    def loss(w):
        wq = quant.fake_quant_ste(w, bits=8, axis=0)
        return jnp.mean((x @ wq - y) ** 2)

    w = jnp.zeros((8, 1))
    l0 = float(loss(w))
    for _ in range(200):
        w = w - 0.1 * jax.grad(loss)(w)
    # convergence to the 8-bit quantization-noise floor (not to zero)
    assert float(loss(w)) < 0.1 * l0


def test_quantize_params_skips_small_leaves():
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 64))
    scale = jax.random.normal(jax.random.PRNGKey(1), (64,))
    q = quant.quantize_params({"w": w, "scale": scale}, bits=8)
    assert float(jnp.abs(q["w"] - w).max()) > 0          # quantized
    np.testing.assert_array_equal(np.asarray(q["scale"]),
                                  np.asarray(scale))      # untouched
