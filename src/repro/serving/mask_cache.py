"""Temporal RoI-mask reuse: the near-sensor trick that makes MGNet ~free.

Consecutive video frames are highly correlated, so the RoI mask rarely
changes between them. The cache re-runs MGNet only when

  * ``refresh`` frames have elapsed since the last scoring (staleness bound),
  * or the cheap frame-delta signal (mean |frame - last_scored_frame|)
    exceeds ``delta_threshold`` — motion or a scene cut;

otherwise the cached region scores are reused verbatim. The decision walk is
sequential (frame i's reference is the most recent *scored* frame before it)
and runs on host numpy; the frames that do need scoring are batched into a
single MGNet call per ingest chunk, so the device sees one static-shaped
score launch instead of per-frame dispatches.
"""

from __future__ import annotations

import numpy as np

from repro.core.mgnet import frame_delta

__all__ = ["TemporalMaskCache"]


class TemporalMaskCache:
    """Per-stream cached MGNet scores + the frame they were computed on."""

    def __init__(self, refresh: int = 8, delta_threshold: float = 0.15):
        if refresh < 1:
            raise ValueError("refresh must be >= 1")
        self.refresh = refresh
        self.delta_threshold = delta_threshold
        self._ref_frame: np.ndarray | None = None    # last scored frame
        self._ref_scores: np.ndarray | None = None   # its region scores (N,)
        self._ref_idx: int = -(1 << 30)
        self.scored_frames = 0
        self.reused_frames = 0

    def reset(self) -> None:
        self.__init__(self.refresh, self.delta_threshold)

    def _needs_refresh(self, frame: np.ndarray, idx: int,
                       ref: np.ndarray | None, ref_idx: int) -> bool:
        if ref is None or idx - ref_idx >= self.refresh:
            return True
        delta = float(frame_delta(frame[None], ref)[0])   # host-side numpy
        return delta > self.delta_threshold

    def gate(self, frames, frame_idx, score_fn,
             eligible=None) -> tuple[np.ndarray, int]:
        """RoI-gate one chunk of consecutive frames.

        frames: (C, H, W, 3); frame_idx: (C,) absolute stream positions;
        score_fn: (m, H, W, 3) -> (m, N) region scores (MGNet forward);
        eligible: optional (C,) bool — frames marked False are never scored,
        never update the reference, and don't enter the reuse stats (the
        engine's beyond-``n_frames`` tail of a final chunk). Their score
        rows are cached filler; callers must not consume them.
        Returns (scores (C, N) np.float32, n_scored_this_chunk).
        """
        frames = np.asarray(frames)
        frame_idx = [int(i) for i in np.asarray(frame_idx)]
        c = frames.shape[0]
        eligible = (np.ones(c, bool) if eligible is None
                    else np.asarray(eligible, bool))

        flags = np.zeros(c, bool)
        ref, ref_idx = self._ref_frame, self._ref_idx
        for i in range(c):
            if eligible[i] and self._needs_refresh(frames[i], frame_idx[i],
                                                   ref, ref_idx):
                flags[i] = True
                ref, ref_idx = frames[i], frame_idx[i]

        n_scored = int(flags.sum())
        if n_scored:
            # pad the to-score subset to the full chunk so ``score_fn`` sees
            # ONE static shape for the whole stream (no per-count retraces —
            # the same shape-stability discipline as the bucket ladder).
            sub = np.zeros_like(frames)
            sub[:n_scored] = frames[flags]
            fresh = np.asarray(score_fn(sub), np.float32)[:n_scored]
        out = []
        cached = self._ref_scores
        j = 0
        for i in range(c):
            if flags[i]:
                cached = fresh[j]
                j += 1
            if cached is None:
                raise ValueError("mask cache is empty and no eligible frame "
                                 "was scored — nothing to reuse")
            out.append(cached)
        scores = np.stack(out).astype(np.float32)

        # persist the newest reference for the next chunk
        if n_scored:
            last = int(np.flatnonzero(flags)[-1])
            self._ref_frame = frames[last]
            self._ref_scores = fresh[-1]
            self._ref_idx = frame_idx[last]
        self.scored_frames += n_scored
        self.reused_frames += int(eligible.sum()) - n_scored
        return scores, n_scored

    @property
    def reuse_rate(self) -> float:
        tot = self.scored_frames + self.reused_frames
        return self.reused_frames / tot if tot else 0.0

    # -- checkpoint/migration ---------------------------------------------

    def state_dict(self) -> dict:
        """Snapshot the gating walk's full state: the reference frame and
        its scores (arrays, or None before anything was scored), the
        reference index, and the reuse counters. A restored cache makes
        the *same* refresh-vs-reuse decision on the next frame the
        original would have — the bitwise-resume requirement."""
        return {
            "ref_frame": (None if self._ref_frame is None
                          else np.asarray(self._ref_frame)),
            "ref_scores": (None if self._ref_scores is None
                           else np.asarray(self._ref_scores)),
            "ref_idx": int(self._ref_idx),
            "scored_frames": int(self.scored_frames),
            "reused_frames": int(self.reused_frames),
        }

    def load_state(self, state: dict) -> None:
        self._ref_frame = (None if state["ref_frame"] is None
                           else np.asarray(state["ref_frame"]))
        self._ref_scores = (None if state["ref_scores"] is None
                            else np.asarray(state["ref_scores"]))
        self._ref_idx = int(state["ref_idx"])
        self.scored_frames = int(state["scored_frames"])
        self.reused_frames = int(state["reused_frames"])
