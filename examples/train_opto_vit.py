"""Train an Opto-ViT (QAT + MGNet) end to end on the synthetic RoI task.

Two phases, mirroring the paper's §IV training pipeline:
  1. MGNet trained with BCE against box-derived patch labels (Eq. 3
     scoring head), evaluated by mask mIoU,
  2. the 8-bit-QAT ViT backbone trained on classification with MGNet
     pruning active (straight-through estimator end to end).

Runs in ~2-4 minutes on CPU with the reduced config; scale --d-model /
--layers / --img up on real hardware (the code path is identical).

    PYTHONPATH=src python examples/train_opto_vit.py --steps 200
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import smoke_variant
from repro.configs.opto_vit import get_config
from repro.core.mgnet import (MGNetConfig, bce_loss, init_mgnet, mask_iou,
                              mgnet_scores)
from repro.data.pipeline import ImageStream
from repro.models.vit import forward_vit, init_vit


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--lr", type=float, default=0.02)
    ap.add_argument("--keep", type=float, default=0.5)
    args = ap.parse_args()

    stream = ImageStream(img_size=32, global_batch=args.batch, n_classes=8,
                         patch=8, seed=0)

    # ---- phase 1: MGNet ----------------------------------------------
    mcfg = MGNetConfig(patch=8, embed=32, heads=2, img_size=32)
    mparams = init_mgnet(jax.random.PRNGKey(0), mcfg)

    @jax.jit
    def mgnet_step(p, batch):
        def loss(p):
            return bce_loss(mgnet_scores(p, batch["images"], mcfg),
                            batch["patch_mask"])
        l, g = jax.value_and_grad(loss)(p)
        return jax.tree_util.tree_map(lambda a, b: a - 0.05 * b, p, g), l

    t0 = time.time()
    for i in range(args.steps):
        mparams, ml = mgnet_step(mparams, stream.batch_at(i))
    val = stream.batch_at(9999)
    pred = (jax.nn.sigmoid(mgnet_scores(mparams, val["images"], mcfg))
            > mcfg.t_reg).astype(jnp.float32)
    miou = float(mask_iou(pred, val["patch_mask"]))
    print(f"[mgnet] {args.steps} steps in {time.time() - t0:.0f}s; "
          f"BCE {float(ml):.3f}; mask mIoU {miou:.3f}")

    # ---- phase 2: QAT ViT backbone with RoI pruning --------------------
    cfg = smoke_variant(get_config("tiny")).with_(
        n_layers=2, remat=False, quant_bits=8,
        mgnet=True, mgnet_keep_ratio=args.keep,
        mgnet_embed=mcfg.embed, mgnet_heads=mcfg.heads)
    params = init_vit(jax.random.PRNGKey(1), cfg, n_classes=8)
    params["mgnet"] = mparams          # plug the trained MGNet in

    def loss_fn(p, batch):
        lg, _ = forward_vit(p, batch["images"], cfg)
        lf = lg.astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(lf, -1)
        gold = jnp.take_along_axis(lf, batch["labels"][:, None], -1)[:, 0]
        return (lse - gold).mean()

    @jax.jit
    def vit_step(p, batch):
        l, g = jax.value_and_grad(loss_fn)(p, batch)
        return jax.tree_util.tree_map(lambda a, b: a - args.lr * b, p, g), l

    t0 = time.time()
    losses = []
    for i in range(args.steps):
        params, l = vit_step(params, stream.batch_at(10000 + i))
        losses.append(float(l))
        if i % 50 == 0:
            print(f"[vit] step {i:4d} loss {float(l):.4f}")

    correct = total = 0
    for j in range(4):
        b = stream.batch_at(20000 + j)
        lg, kept = forward_vit(params, b["images"], cfg)
        correct += int((jnp.argmax(lg, -1) == b["labels"]).sum())
        total += int(b["labels"].shape[0])
    print(f"[vit] {args.steps} QAT steps in {time.time() - t0:.0f}s; "
          f"loss {losses[0]:.3f} -> {np.mean(losses[-10:]):.3f}; "
          f"val acc {correct / total:.3f} with {kept}/{16} patches kept")


if __name__ == "__main__":
    main()
