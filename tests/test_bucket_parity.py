"""Bucketed-pruning parity, pinned regression cases: ``forward_vit_tokens``
on top-k-gathered tokens must match mask-mode dense logits with the same k
patches kept — per backend, including the Pallas kernel in interpret mode.

Why this must hold: LayerNorm and the FFN are per-token, so attention is the
only cross-token operator in the trunk; the key-axis mask assigns dropped
tokens exactly-zero probability weight, making every kept token's activation
independent of whether dropped tokens are physically present. Float paths
therefore agree to reassociation noise. Quantizing backends agree only to
quantization noise: the per-tensor activation absmax is taken over a
different token set in the two modes (dropped rows still flow through the
masked forward), so the scales — and hence the int8 codes — can differ.

The former full backend x bucket cross product lives on as *generated*
budgets in tests/test_differential.py (hypothesis); this file keeps the
cheap float sweep plus one pinned ladder pair per quantizing backend (the
0.999-correlation regression anchors).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import smoke_variant
from repro.configs.opto_vit import get_config
from repro.core.backend import prepare_params
from repro.core.mgnet import select_topk_patches
from repro.models.vit import (embed_patches, forward_vit_masked,
                              forward_vit_tokens, init_vit)
from repro.serving.buckets import BucketLadder

N_PATCHES = 16
LADDER = BucketLadder.from_fractions(N_PATCHES)          # (4, 8, 12, 16)


@pytest.fixture(scope="module")
def base_cfg():
    return smoke_variant(get_config("tiny")).with_(n_layers=2)


@pytest.fixture(scope="module")
def params(base_cfg):
    return init_vit(jax.random.PRNGKey(1), base_cfg, n_classes=8)


@pytest.fixture(scope="module")
def images():
    return jax.random.normal(jax.random.PRNGKey(0), (2, 32, 32, 3))


@pytest.fixture(scope="module")
def scores():
    # includes exact ties so routing hits the deterministic tie-break
    s = jax.random.normal(jax.random.PRNGKey(2), (2, N_PATCHES))
    return s.at[:, 5].set(s[:, 3])


def _mask_from_idx(idx, n):
    b = idx.shape[0]
    return jnp.zeros((b, n)).at[jnp.arange(b)[:, None], idx].set(1.0)


# float path: the full ladder is cheap; quantizing backends keep one
# mid-ladder + the all-ones edge (k == N, where both modes quantize the
# same token set) — generated budgets cover the rest (test_differential).
PINNED_CASES = ([("bf16", k) for k in LADDER.sizes]
                + [(b, k) for b in ("qat", "photonic_sim", "photonic_pallas")
                   for k in (8, N_PATCHES)])


@pytest.mark.parametrize("backend,k", PINNED_CASES)
def test_gathered_topk_matches_masked_dense(base_cfg, params, images, scores,
                                            backend, k):
    cfg = base_cfg.with_(matmul_backend=backend,
                         quant_bits=0 if backend == "bf16" else 8)
    p = (prepare_params(params, bits=8)
         if backend.startswith("photonic") else params)

    toks = embed_patches(p, images, cfg)
    pruned, idx = select_topk_patches(scores, toks, k)
    lg_topk, kept = forward_vit_tokens(p, pruned, cfg)
    assert kept == k
    lg_mask, _ = forward_vit_masked(p, images, _mask_from_idx(idx, N_PATCHES),
                                    cfg)

    a, b = np.asarray(lg_topk, np.float32), np.asarray(lg_mask, np.float32)
    if backend == "bf16" or k == N_PATCHES:
        # float path (or all-ones mask, where both modes quantize the same
        # token set): reassociation noise only
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
    else:
        # w8a8 paths: per-tensor activation scales differ between the two
        # token sets -> agreement up to 8-bit quantization noise
        assert np.corrcoef(a.ravel(), b.ravel())[0, 1] > 0.999
        np.testing.assert_allclose(a, b, rtol=0.35, atol=0.35)
