"""Feed-forward blocks: SwiGLU (LM default) and GELU-MLP (ViT/Whisper).

The GELU-MLP routes through ``core.backend.ffn`` — the FFN backend
registry (xla composed two-linear | fused int8 photonic kernel, selected
by ``ArchConfig.ffn_backend`` / ``ExecPolicy.ffn_backend``) — so the
serving hot path can collapse both matmuls, the GELU and the hidden
requantization into one kernel without the callers changing.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.backend import ffn as ffn_dispatch
from repro.distributed.sharding import shard
from repro.models.layers import ExecPolicy, he_init, linear

__all__ = ["init_swiglu", "swiglu", "init_mlp", "mlp",
           "swiglu_logical_axes", "mlp_logical_axes"]


def init_swiglu(key, d: int, d_ff: int, dtype=jnp.bfloat16) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w_gate": he_init(k1, (d, d_ff), dtype),
            "w_up": he_init(k2, (d, d_ff), dtype),
            "w_down": he_init(k3, (d_ff, d), dtype)}


def swiglu_logical_axes() -> dict:
    return {"w_gate": ("p_embed", "p_mlp"),
            "w_up": ("p_embed", "p_mlp"),
            "w_down": ("p_mlp", "p_embed")}


def swiglu(params: dict, x: jnp.ndarray, policy: ExecPolicy | None = None):
    """x: (B, S, d) -> (B, S, d); hidden sharded on the TP axis."""
    g = linear(x, params["w_gate"], policy=policy)
    u = linear(x, params["w_up"], policy=policy)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = shard(h, "batch", "seq", "mlp")
    return linear(h, params["w_down"], policy=policy)


def init_mlp(key, d: int, d_ff: int, dtype=jnp.bfloat16) -> dict:
    k1, k2 = jax.random.split(key)
    return {"w1": he_init(k1, (d, d_ff), dtype), "b1": jnp.zeros((d_ff,), dtype),
            "w2": he_init(k2, (d_ff, d), dtype), "b2": jnp.zeros((d,), dtype)}


def mlp_logical_axes() -> dict:
    return {"w1": ("p_embed", "p_mlp"), "b1": ("p_mlp",),
            "w2": ("p_mlp", "p_embed"), "b2": ("p_embed",)}


def mlp(params: dict, x: jnp.ndarray, policy: ExecPolicy | None = None,
        live_rows: int | None = None):
    """x (..., n, d) -> (..., n, d) through the FFN backend registry.

    ``live_rows`` is the packed one-shape serving hint: a static live
    token count that skipping backends (``ffn_backend="fused"``) use to
    drop fully-pruned rows before any FLOP (dead rows return exact 0, so
    the residual add leaves their stream state untouched); the composed
    xla backend ignores it.
    """
    return ffn_dispatch(x, params["w1"], params["b1"],
                        params["w2"], params["b2"], policy,
                        live_rows=live_rows)
