"""Streaming video serving (ingest -> RoI gate -> bucket -> encode ->
account). ``repro.serving.server`` is the multi-stream session server
(shared jit ladder, cross-stream micro-batching, mesh-sharded encode);
``repro.serving.engine`` the single-session compatibility shell."""

from repro.serving.accounting import StreamAccounting
from repro.serving.buckets import BucketHistogram, BucketLadder
from repro.serving.engine import ServingEngine, main
from repro.serving.faults import (FaultInjector, FaultSpec, ServeError,
                                  SessionFailure, serve_with_restarts)
from repro.serving.mask_cache import TemporalMaskCache
from repro.serving.scheduler import FrameBatch, MicroBatcher
from repro.serving.server import ServerConfig, StreamServer
from repro.serving.session import ServingConfig, StreamResult, StreamSession

__all__ = ["ServingEngine", "ServingConfig", "StreamResult", "BucketLadder",
           "BucketHistogram", "TemporalMaskCache", "MicroBatcher",
           "FrameBatch", "StreamAccounting", "StreamServer", "ServerConfig",
           "StreamSession", "FaultSpec", "FaultInjector", "ServeError",
           "SessionFailure", "serve_with_restarts", "main"]
