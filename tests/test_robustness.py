"""Robustness serving tests: clean-path bitwise guarantee, noisy dispatch
mechanics, and drift-triggered recalibration.

The load-bearing contract of the calibrated noise layer (core/noise.py +
ExecPolicy.noise) is that it is *free when off*: noise-disabled serving must
stay bitwise identical to the pre-noise engine on every backend combo. The
GOLDEN tables below pin the exact predictions captured before the noise
layer landed — if a refactor perturbs the clean path by one ulp anywhere,
these argmaxes move and the pin fails. The noisy path's own contracts
(scope-required, per-frame freshness, pinned reproduction, fused fallback,
recalibration + billing) are covered alongside.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.backend import (ExecPolicy, prepare_params,
                                reset_fused_fallback_warnings)
from repro.core.noise import DriftState, NoiseSpec, scoped
from repro.data.pipeline import VideoStream
from repro.models.vit import forward_vit, init_vit
from repro.serving.accounting import retune_report
from repro.serving.engine import _smoke_cfg
from repro.serving.server import ServerConfig, StreamServer

# Predictions of the pre-noise-layer engine: smoke cfg, seed-0 server,
# seed-3 stream, 16 frames (chunk 4, microbatch 2, no warm start, no mesh).
GOLDEN_CLEAN = {
    ("photonic_sim", "", ""): [7, 9, 3, 8, 8, 3, 6, 3, 1, 3, 7, 5, 7, 6, 6, 8],
    ("photonic_pallas", "", ""): [7, 9, 3, 8, 8, 3, 6, 3, 1, 3, 7, 5, 7, 6, 6, 8],
    ("photonic_pallas", "flash", "fused"): [7, 9, 3, 8, 8, 3, 6, 3, 1, 3, 7, 5, 7, 6, 6, 8],
    ("bf16", "", ""): [9, 9, 3, 3, 6, 3, 6, 3, 1, 3, 9, 5, 6, 6, 6, 8],
}


def _serve(combo, noise=None, n_frames=16):
    backend, attn, ffn = combo
    cfg = _smoke_cfg(backend, attn, ffn)
    if noise is not None:
        cfg = cfg.with_(noise=noise)
    sc = ServerConfig(warm_start=False, mesh="off", chunk=4, microbatch=2)
    srv = StreamServer(cfg, sc, seed=0)
    st = VideoStream(img_size=cfg.img_size, patch=cfg.patch, seed=3,
                     cut_every=8)
    s = srv.add_session(st, n_frames=n_frames)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        res = srv.serve()[s.sid]
    return [res.predictions[i] for i in range(n_frames)], srv, res


@pytest.mark.parametrize("combo", list(GOLDEN_CLEAN),
                         ids=lambda c: "+".join(x for x in c if x) or c[0])
def test_clean_serving_bitwise_pinned(combo):
    """Noise-disabled serving reproduces the pre-noise-layer predictions
    exactly — the noise layer must be invisible when off."""
    preds, srv, _ = _serve(combo)
    assert srv.noise is None and srv.drift is None
    assert preds == GOLDEN_CLEAN[combo], (combo, preds)


def _smoke_forward_setup(backend="photonic_pallas", attn="", ffn="",
                         spec=None):
    cfg = _smoke_cfg(backend, attn, ffn).with_(mgnet=False)
    if spec is not None:
        cfg = cfg.with_(noise=spec)
    params = prepare_params(
        init_vit(jax.random.PRNGKey(0), cfg, n_classes=4), bits=8)
    imgs = jax.random.normal(jax.random.PRNGKey(1), (2, cfg.img_size,
                                                     cfg.img_size, 3))
    return cfg, params, imgs


def test_noisy_forward_requires_scope():
    """ExecPolicy.noise without an installed noise scope must raise — the
    replacement for the old silent frozen-PRNGKey(0) fallback."""
    cfg, params, imgs = _smoke_forward_setup(spec=NoiseSpec())
    with pytest.raises(RuntimeError, match="no noise scope"):
        forward_vit(params, imgs, cfg)


def test_noisy_forward_fresh_per_frame_pinned_reproduces():
    spec = NoiseSpec()
    cfg, params, imgs = _smoke_forward_setup(spec=spec)
    fwd = jax.jit(lambda p, im, ns: scoped(
        ns, lambda: forward_vit(p, im, cfg)[0]))
    s0 = DriftState.init(0)
    l0 = np.asarray(fwd(params, imgs, s0))
    l0b = np.asarray(fwd(params, imgs, s0))
    np.testing.assert_array_equal(l0, l0b)   # pinned state: bitwise
    l1 = np.asarray(fwd(params, imgs, s0.advance(spec, 1)))
    assert float(np.abs(l1 - l0).max()) > 0  # next frame: fresh draws


def test_noisy_forward_differs_from_clean_but_agrees_loosely():
    cfg_n, params, imgs = _smoke_forward_setup(spec=NoiseSpec())
    cfg_c = cfg_n.with_(noise=None)
    clean = np.asarray(forward_vit(params, imgs, cfg_c)[0])
    noisy = np.asarray(scoped(DriftState.init(0),
                              lambda: forward_vit(params, imgs, cfg_n)[0]))
    diff = float(np.abs(noisy - clean).max())
    assert diff > 0
    # calibrated point: perturbation, not destruction
    corr = float(np.corrcoef(noisy.ravel(), clean.ravel())[0, 1])
    assert corr > 0.9, corr


def test_fused_paths_fall_back_under_noise():
    """Requesting flash+fused with noise active must warn (once per cause)
    and take the composed analog dispatch — the fused int8 kernels are the
    clean digital contract."""
    reset_fused_fallback_warnings()
    cfg, params, imgs = _smoke_forward_setup(attn="flash", ffn="fused",
                                             spec=NoiseSpec())
    with pytest.warns(UserWarning, match="noise"):
        scoped(DriftState.init(0),
               lambda: forward_vit(params, imgs, cfg)[0])


def test_gate_stays_clean_under_noise_by_default():
    """Routing determinism: the MGNet gate runs the clean policy unless
    noisy_gate opts in, so clean and noisy servers bucket identically."""
    p = ExecPolicy(backend="photonic_pallas", noise=NoiseSpec())
    assert p.gate_policy().noise is None
    pg = ExecPolicy(backend="photonic_pallas",
                    noise=NoiseSpec(noisy_gate=True))
    assert pg.gate_policy().noise is not None
    clean = ExecPolicy(backend="photonic_pallas")
    assert clean.without_noise() is clean


def test_noisy_serving_routes_like_clean():
    spec = NoiseSpec()
    preds_c, _, res_c = _serve(("photonic_pallas", "", ""))
    preds_n, srv, res_n = _serve(("photonic_pallas", "", ""), noise=spec)
    assert res_n.bucket_hits == res_c.bucket_hits
    assert res_n.frames == res_c.frames
    # same length / frame coverage; predictions may differ under noise
    assert len(preds_n) == len(preds_c)


def test_drift_triggered_recalibration_and_billing():
    spec = NoiseSpec(drift_rate_nm=0.01, recal_bound_nm=0.08)
    preds, srv, res = _serve(("photonic_pallas", "", ""), noise=spec,
                             n_frames=16)
    # 16 frames * 0.01 nm crosses the 0.08 bound twice
    assert srv.recalibrations >= 1
    assert res.recalibrations == srv.recalibrations
    assert srv._host_drift_nm < spec.recal_bound_nm
    assert float(srv.drift.drift_nm) < spec.recal_bound_nm
    # the re-tune was billed: same frames, more energy than without drift
    _, _, res_nodrift = _serve(("photonic_pallas", "", ""),
                               noise=NoiseSpec(), n_frames=16)
    assert res.frames == res_nodrift.frames
    assert res_nodrift.recalibrations == 0
    assert res.mean_frame_uj > res_nodrift.mean_frame_uj


def test_inject_drift_requires_noise_and_recal_resets():
    _, srv, _ = _serve(("photonic_pallas", "", ""))
    with pytest.raises(ValueError, match="noise"):
        srv.inject_drift(0.5)

    spec = NoiseSpec(recal_bound_nm=0.2)
    cfg = _smoke_cfg("photonic_pallas").with_(noise=spec)
    sc = ServerConfig(warm_start=False, mesh="off", chunk=4, microbatch=2)
    srv = StreamServer(cfg, sc, seed=0)
    srv.inject_drift(0.5)
    assert srv._host_drift_nm == pytest.approx(0.5)
    srv._advance_drift(1)          # bound check runs -> recalibrate
    assert srv.recalibrations == 1
    assert srv._host_drift_nm == 0.0
    assert float(srv.drift.drift_nm) == 0.0


def test_retune_report_positive_and_width_scaled():
    cfg = _smoke_cfg("photonic_pallas")
    full = retune_report(cfg)
    assert full.total_uj > 0
    mixed = retune_report(cfg, layer_bits=(4,) * cfg.n_layers)
    assert 0 < mixed.total_uj < full.total_uj


def test_policy_fingerprint_carries_noise():
    a = ExecPolicy(backend="photonic_pallas")
    b = ExecPolicy(backend="photonic_pallas", noise=NoiseSpec())
    assert a.fingerprint() != b.fingerprint()
    assert b.without_noise().fingerprint() == a.fingerprint()
