"""Fault-tolerant serving tests: injector determinism + inertness, retry
transparency, per-session quarantine, ServeError attribution with partial
results, checkpoint/restore + migration bitwise round-trips, checkpoint
I/O fault tolerance, load shedding, the flush watchdog, and
``serve_with_restarts`` crash recovery."""

import warnings

import numpy as np
import pytest

from repro.core.noise import NoiseSpec
from repro.data.pipeline import video_fleet
from repro.serving.engine import _smoke_cfg
from repro.serving.faults import (FatalFault, FaultInjector, FaultSpec,
                                  ServeError, TransientFault,
                                  serve_with_restarts)
from repro.serving.server import ServerConfig, StreamServer

N_FRAMES = 24


def _server(cfg, **kw):
    base = dict(warm_start=False, mesh="off", chunk=8, microbatch=4)
    base.update(kw)
    return StreamServer(cfg, ServerConfig(**base))


def _serve(srv, streams, n_frames=N_FRAMES):
    for st in streams:
        srv.add_session(st, n_frames=n_frames)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        return srv.serve()


def _preds(res, n=N_FRAMES):
    return np.array([res.predictions[i] for i in range(n)])


@pytest.fixture(scope="module")
def base3():
    """Default-backend baseline: 3 streams x N_FRAMES, fault-free."""
    cfg = _smoke_cfg("")
    streams = video_fleet(3, img_size=cfg.img_size, patch=cfg.patch)
    res = _serve(_server(cfg), streams)
    return cfg, streams, {sid: _preds(r) for sid, r in res.items()}, res


# --------------------------------------------------------------------------
# injector: determinism, replayability, transient clearing
# --------------------------------------------------------------------------

def test_injector_deterministic_and_order_independent():
    """Fault decisions are a pure function of (seed, site) — two injectors
    agree site-by-site, and probing sites in a different order changes
    nothing (no shared RNG stream to desynchronize)."""
    spec = FaultSpec(flush_fault_rate=0.3, ingest_fault_rate=0.2, seed=42)
    sites = [(k, (sid, f)) for k in (8, 16) for sid in (0, 1)
             for f in range(10)]
    a, b = FaultInjector(spec), FaultInjector(spec)
    hits_a = [a._hit(spec.flush_fault_rate, "flush", k, *t)
              for k, t in sites]
    hits_b = [b._hit(spec.flush_fault_rate, "flush", k, *t)
              for k, t in reversed(sites)]
    assert hits_a == list(reversed(hits_b))
    assert any(hits_a) and not all(hits_a)
    # a different seed draws a different fault pattern
    c = FaultInjector(FaultSpec(flush_fault_rate=0.3, seed=43))
    hits_c = [c._hit(spec.flush_fault_rate, "flush", k, *t)
              for k, t in sites]
    assert hits_a != hits_c


def test_injector_transient_site_clears_after_n_failures():
    """A transient site fails exactly its first ``transient_failures``
    attempts, then succeeds — the retry loop always converges."""
    inj = FaultInjector(FaultSpec(flush_fault_rate=1.0,
                                  transient_failures=2, seed=0))
    for attempt in (0, 1):
        with pytest.raises(TransientFault):
            inj.flush(8, (0, 0), attempt=attempt)
    inj.flush(8, (0, 0), attempt=2)            # cleared
    assert inj.injected["flush_transient"] == 2


def test_injector_hard_fail_targets_one_session():
    inj = FaultInjector(FaultSpec(hard_fail_session=1,
                                  hard_fail_at_chunk=2))
    inj.ingest(0, 2)
    inj.ingest(1, 1)
    with pytest.raises(FatalFault, match="session 1"):
        inj.ingest(1, 2)


# --------------------------------------------------------------------------
# hygiene: no FaultSpec -> no injector, zero-rate spec -> bitwise identical
# --------------------------------------------------------------------------

def test_no_faultspec_means_no_injector(base3):
    cfg, _, _, _ = base3
    srv = _server(cfg)
    assert srv.faults is None and srv._injector is None
    assert srv._watchdog is False and srv.telemetry is None


@pytest.mark.parametrize("backend,attn,ffn", [
    ("bf16", "", ""),
    ("photonic_pallas", "", ""),
])
def test_fault_layer_inert_without_faults(backend, attn, ffn):
    _inertness_case(backend, attn, ffn)


@pytest.mark.slow
@pytest.mark.parametrize("backend,attn,ffn", [
    ("photonic_sim", "", ""),
    ("photonic_pallas", "flash", "fused"),    # the acceptance path
])
def test_fault_layer_inert_without_faults_slow(backend, attn, ffn):
    _inertness_case(backend, attn, ffn)


def _inertness_case(backend, attn, ffn):
    """A zero-rate FaultSpec (injector present, never fires) must serve
    bitwise identically to no spec at all: the fault layer adds no RNG
    draws and no dispatch changes to the hot path."""
    cfg = _smoke_cfg(backend, attn, ffn)
    streams = video_fleet(2, img_size=cfg.img_size, patch=cfg.patch)
    plain = _serve(_server(cfg), streams, n_frames=12)
    spec = FaultSpec(seed=9)                  # all rates zero
    armed = _serve(_server(cfg, faults=spec), streams, n_frames=12)
    for sid in plain:
        np.testing.assert_array_equal(_preds(plain[sid], 12),
                                      _preds(armed[sid], 12))
        assert not armed[sid].poisoned and armed[sid].retries == 0


# --------------------------------------------------------------------------
# retry transparency + quarantine isolation
# --------------------------------------------------------------------------

def test_transient_flush_faults_bitwise_transparent(base3):
    cfg, streams, bp, _ = base3
    srv = _server(cfg, faults=FaultSpec(flush_fault_rate=0.3, seed=7))
    res = _serve(srv, streams)
    assert sum(r.retries for r in res.values()) > 0
    for sid in bp:
        assert not res[sid].poisoned
        np.testing.assert_array_equal(_preds(res[sid]), bp[sid])


def test_ingest_faults_retry_without_losing_chunks(base3):
    cfg, streams, bp, _ = base3
    srv = _server(cfg, faults=FaultSpec(ingest_fault_rate=0.3, seed=11))
    res = _serve(srv, streams)
    assert sum(r.retries for r in res.values()) > 0
    for sid in bp:
        assert res[sid].frames == N_FRAMES
        np.testing.assert_array_equal(_preds(res[sid]), bp[sid])


def test_hard_failed_session_is_quarantined_others_bitwise(base3):
    """Gate B shape: the victim comes back poisoned, the survivors are
    bitwise identical to a run where the victim was never registered."""
    cfg, streams, bp, _ = base3
    srv = _server(cfg, faults=FaultSpec(hard_fail_session=1,
                                        hard_fail_at_chunk=1, seed=1))
    with pytest.warns(UserWarning, match="quarantined session"):
        for st in streams:
            srv.add_session(st, n_frames=N_FRAMES)
        res = srv.serve()
    assert res[1].poisoned and "session 1" in res[1].failure
    assert res[1].frames < N_FRAMES            # partial, not silently full
    for sid in (0, 2):
        assert not res[sid].poisoned
        np.testing.assert_array_equal(_preds(res[sid]), bp[sid])
    # never-registered counterfactual (sids remap by registration order)
    ref = _serve(_server(cfg), [streams[0], streams[2]])
    np.testing.assert_array_equal(_preds(ref[0]), bp[0])
    np.testing.assert_array_equal(_preds(ref[1]), bp[2])


def test_retry_exhaustion_fails_only_owning_session(base3):
    """A permanently-failing flush site (more consecutive failures than
    the retry limit) quarantines its owner; co-tenants still finish."""
    cfg, streams, bp, _ = base3
    spec = FaultSpec(flush_fault_rate=0.15, transient_failures=5, seed=2)
    srv = _server(cfg, faults=spec, retry_limit=2, retry_backoff_s=0.0)
    res = _serve(srv, streams)
    poisoned = [sid for sid, r in res.items() if r.poisoned]
    assert poisoned, "0.15 fault rate with 5x persistence must exhaust " \
                     "the 2-retry budget somewhere"
    for sid, r in res.items():
        if not r.poisoned:
            np.testing.assert_array_equal(_preds(r), bp[sid])
        else:
            assert "retry limit" in r.failure


# --------------------------------------------------------------------------
# ServeError: attribution + partial results
# --------------------------------------------------------------------------

def test_serve_error_attributes_bucket_session_round(base3):
    cfg, streams, _, _ = base3
    srv = _server(cfg)
    srv.add_session(streams[0], n_frames=8)

    def boom(fb, by_sid):
        raise RuntimeError("encode died")
    srv._finish = boom
    with pytest.raises(ServeError, match="encode died") as ei:
        srv.serve()
    e = ei.value
    assert "bucket k=" in str(e) and "round" in str(e)
    assert e.context["sessions"] == [0]
    assert e.context["round"] == 0
    assert srv._sessions == [] and srv._inflight is None


def test_serve_error_carries_partials_for_drained_sessions(base3):
    """When the loop dies after one session fully drained, that session's
    finished StreamResult rides out on the ServeError instead of being
    thrown away with the wreckage."""
    cfg, streams, bp, _ = base3
    srv = _server(cfg)
    srv.add_session(streams[0], n_frames=8)    # drains quickly
    s1 = srv.add_session(streams[1], n_frames=N_FRAMES)
    real = srv._finish

    def sabotage(fb, by_sid):
        owners = {sid for sid, _ in fb.frame_idx}
        if owners == {s1.sid} and s1.acct.frames >= 16:
            raise RuntimeError("device lost")
        return real(fb, by_sid)
    srv._finish = sabotage
    with pytest.raises(ServeError, match="device lost") as ei:
        srv.serve()
    partial = ei.value.partial_results
    assert list(partial) == [0]
    assert partial[0].frames == 8
    np.testing.assert_array_equal(_preds(partial[0], 8), bp[0][:8])


# --------------------------------------------------------------------------
# checkpoint round-trip, migration, restarts
# --------------------------------------------------------------------------

def test_checkpoint_roundtrip_bitwise(tmp_path, base3):
    """Satellite (c): pause -> checkpoint -> restore in a fresh server;
    the resumed serve's predictions, accounting totals, and mask-cache
    hit behavior all match the uninterrupted run exactly."""
    cfg, streams, bp, base = base3
    srv = _server(cfg)
    for st in streams:
        srv.add_session(st, n_frames=N_FRAMES)
    assert srv.serve(max_rounds=1) == {}       # paused mid-stream
    srv.checkpoint(root=str(tmp_path))

    srv2 = _server(cfg)
    sessions = srv2.restore_checkpoint(str(tmp_path),
                                       streams=dict(enumerate(streams)))
    assert sorted(sessions) == [0, 1, 2]
    res = srv2.serve()
    for sid in bp:
        np.testing.assert_array_equal(_preds(res[sid]), bp[sid])
        assert res[sid].frames == base[sid].frames
        assert res[sid].scored_frames == base[sid].scored_frames
        assert res[sid].reused_frames == base[sid].reused_frames
        assert res[sid].bucket_hits == base[sid].bucket_hits
        assert res[sid].mean_frame_uj == base[sid].mean_frame_uj


@pytest.mark.slow
def test_checkpoint_roundtrip_noisy_drift_bitwise(tmp_path):
    """Under calibrated noise the server-owned DriftState (thermal time
    index) must round-trip bitwise: the resumed noisy serve equals the
    uninterrupted noisy serve frame-for-frame."""
    cfg = _smoke_cfg("photonic_pallas").with_(
        noise=NoiseSpec(drift_rate_nm=0.002, seed=3))
    streams = video_fleet(2, img_size=cfg.img_size, patch=cfg.patch)
    base = _serve(_server(cfg), streams)
    srv = _server(cfg)
    for st in streams:
        srv.add_session(st, n_frames=N_FRAMES)
    assert srv.serve(max_rounds=1) == {}
    srv.checkpoint(root=str(tmp_path))
    srv2 = _server(cfg)
    srv2.restore_checkpoint(str(tmp_path), streams=dict(enumerate(streams)))
    assert np.asarray(srv2.drift.frame) == np.asarray(srv.drift.frame)
    res = srv2.serve()
    for sid, r in base.items():
        np.testing.assert_array_equal(_preds(res[sid]), _preds(r))
    # thermal time index ends exactly where the uninterrupted run's does
    assert int(np.asarray(srv2.drift.frame)) == N_FRAMES * 2
    assert float(np.asarray(srv2.drift.drift_nm)) == pytest.approx(
        N_FRAMES * 2 * cfg.noise.drift_rate_nm, abs=1e-5)


def test_migration_export_adopt_bitwise(base3):
    cfg, streams, bp, _ = base3
    srv_a = _server(cfg)
    for st in streams:
        srv_a.add_session(st, n_frames=N_FRAMES)
    assert srv_a.serve(max_rounds=1) == {}
    snap = srv_a.export_session(1)
    assert snap["meta"]["sid"] == 1
    srv_b = _server(cfg)
    srv_b.adopt_session(snap, stream=streams[1])
    res_b = srv_b.serve()
    res_a = srv_a.serve()
    np.testing.assert_array_equal(_preds(res_b[1]), bp[1])
    np.testing.assert_array_equal(_preds(res_a[0]), bp[0])
    np.testing.assert_array_equal(_preds(res_a[2]), bp[2])
    assert 1 not in res_a


def test_checkpoint_refused_under_mix_streams(base3):
    cfg, streams, _, _ = base3
    srv = _server(cfg, mix_streams=True)
    srv.add_session(streams[0], n_frames=8)
    with pytest.raises(ValueError, match="mix_streams"):
        srv.checkpoint(root="/tmp/nope")


def test_checkpoint_fault_degrades_gracefully(tmp_path, base3):
    """Checkpoint I/O loss must not take serving down: the round keeps
    going on the last good snapshot and the failure is counted."""
    cfg, streams, bp, _ = base3
    srv = _server(cfg, faults=FaultSpec(checkpoint_fault_rate=1.0, seed=4),
                  checkpoint_dir=str(tmp_path), checkpoint_every=1)
    res = _serve(srv, streams)
    assert srv.checkpoint_failures > 0
    for sid in bp:
        np.testing.assert_array_equal(_preds(res[sid]), bp[sid])


def test_serve_with_restarts_resumes_bitwise(tmp_path, base3):
    cfg, streams, bp, base = base3

    def make_server(attempt):
        faults = FaultSpec(crash_at_round=2, seed=5) if attempt == 0 else None
        return _server(cfg, faults=faults, checkpoint_dir=str(tmp_path),
                       checkpoint_every=1)

    def register(srv):
        for st in streams:
            srv.add_session(st, n_frames=N_FRAMES)

    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        res, restarts, _ = serve_with_restarts(
            make_server, register, str(tmp_path),
            streams=dict(enumerate(streams)))
    assert restarts == 1
    for sid in bp:
        np.testing.assert_array_equal(_preds(res[sid]), bp[sid])
        assert res[sid].frames == base[sid].frames


# --------------------------------------------------------------------------
# graceful degradation: load shedding + flush watchdog
# --------------------------------------------------------------------------

def test_load_shedding_bounds_queue_and_accounts_drops(base3):
    cfg, streams, _, _ = base3
    srv = _server(cfg, max_pending_rows=4)
    res = _serve(srv, streams[:2])
    assert sum(r.shed_frames for r in res.values()) > 0
    for r in res.values():
        assert r.frames + r.shed_frames == N_FRAMES
        assert not r.poisoned


def test_watchdog_flags_injected_stragglers(base3):
    cfg, streams, _, _ = base3
    srv = _server(cfg, watchdog=True,
                  faults=FaultSpec(stall_rate=0.15, stall_s=0.05, seed=6))
    _serve(srv, streams)
    assert srv.telemetry is not None
    assert srv.telemetry.total_recorded >= 10
    assert len(srv.straggler_flags) > 0
    # flagged observations really are the stalled flushes: each took
    # longer than the stall floor
    assert all(o.wall_s >= 0.05 for o in srv.straggler_flags)
