"""Fault tolerance: auto-restart resume, determinism, straggler flags."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import CheckpointManager
from repro.data.pipeline import ImageStream, TokenStream
from repro.distributed.fault_tolerance import StragglerDetector, run_with_restarts


def test_run_with_restarts_resumes_from_checkpoint(tmp_path):
    """A fault at step 7 must restart from the step-5 checkpoint and end
    with the same final state as a fault-free run (state = pure function
    of step count)."""
    mgr = CheckpointManager(str(tmp_path), every=5, keep=3)
    faults = {"armed": True}

    def step_fn(state, step):
        if step == 7 and faults["armed"]:
            faults["armed"] = False
            raise RuntimeError("injected preemption")
        return {"x": state["x"] + 1.0, "hist": state["hist"] + step}

    init = {"x": jnp.zeros(()), "hist": jnp.zeros(())}
    final, restarts = run_with_restarts(step_fn, init, 10, mgr)
    assert restarts == 1
    assert float(final["x"]) == 10.0
    assert float(final["hist"]) == sum(range(10))


def test_restart_gives_bit_identical_stream(tmp_path):
    """Data pipeline is (seed, step)-indexed: a resumed run consumes
    exactly the batches the lost run would have."""
    s1 = TokenStream(vocab=64, seq_len=8, global_batch=2, seed=3)
    s2 = TokenStream(vocab=64, seq_len=8, global_batch=2, seed=3)
    for step in (0, 5, 17):
        a = s1.batch_at(step)
        b = s2.batch_at(step)
        np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                      np.asarray(b["tokens"]))
    img1 = ImageStream(img_size=32, global_batch=2, seed=1)
    img2 = ImageStream(img_size=32, global_batch=2, seed=1)
    np.testing.assert_array_equal(np.asarray(img1.batch_at(9)["images"]),
                                  np.asarray(img2.batch_at(9)["images"]))


def test_max_restarts_exceeded(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every=100)

    def step_fn(state, step):
        raise RuntimeError("permafail")

    with pytest.raises(RuntimeError, match="permafail"):
        run_with_restarts(step_fn, {"x": jnp.zeros(())}, 5, mgr,
                          max_restarts=2)


def test_straggler_detector_flags_outlier():
    det = StragglerDetector(k=5.0)
    for step in range(20):
        det.record(step, 0.10 + 0.001 * (step % 3))
    assert det.record(20, 0.5) is True       # 5x median
    assert det.record(21, 0.101) is False
    assert len(det.flags) == 1


def test_straggler_detector_warmup_quiet():
    det = StragglerDetector()
    for step in range(9):                     # < 10 samples: never flags
        assert det.record(step, 100.0 * (step + 1)) is False
