"""Multi-stream session server tests: interleaved-vs-sequential bitwise
parity (per backend combo), per-session fairness under a bursty stream,
deadline-flush padding hygiene, warm-start jit ladder, dead-bucket
trimming, the scheduler's row storage + flush_stale surfaces, and the
mesh-sharded encode path (subprocess, forced multi-device host)."""

import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import VideoStream, video_fleet
from repro.serving.buckets import BucketLadder
from repro.serving.engine import ServingEngine, _smoke_cfg
from repro.serving.scheduler import MicroBatcher
from repro.serving.server import (ServerConfig, StreamServer,
                                  interleave_rounds)
from repro.serving.session import ServingConfig


# --------------------------------------------------------------------------
# scheduler: row storage, flush_stale, drain(select)
# --------------------------------------------------------------------------

def test_push_stores_bare_rows_until_flush():
    """Single-frame pushes keep the bare (k, d) row in the queue (no
    per-frame [None] copy); rank expansion happens once, at flush."""
    mb = MicroBatcher(microbatch=3)
    rows = [jnp.full((2, 5), float(i)) for i in range(3)]
    assert mb.push(8, rows[0], 0) == []
    assert mb.push(8, rows[1], 1) == []
    (tokens, idxs, _, is_row), = mb._queues[8][:1]
    assert is_row and tokens.shape == (2, 5)         # still a bare row
    (fb,) = mb.push(8, rows[2], 2)
    assert fb.n_real == 3 and fb.frame_idx == [0, 1, 2]
    np.testing.assert_array_equal(np.asarray(fb.tokens),
                                  np.stack([np.asarray(r) for r in rows]))


def test_push_rows_and_groups_mix_in_order():
    mb = MicroBatcher(microbatch=4)
    group = jnp.arange(2 * 3 * 2, dtype=jnp.float32).reshape(2, 3, 2)
    row = jnp.full((3, 2), 9.0)
    assert mb.push_many(4, group, [0, 1]) == []
    assert mb.push(4, row, 2) == []
    (fb,) = mb.push(4, row + 1, 3)
    assert fb.frame_idx == [0, 1, 2, 3]
    np.testing.assert_array_equal(np.asarray(fb.tokens[2]), np.asarray(row))


def test_flush_stale_honors_deadline_and_pads():
    mb = MicroBatcher(microbatch=4)
    old = jnp.ones((2, 3, 2))
    new = jnp.ones((1, 3, 2))
    mb.push_many(8, old, [0, 1], now=0)
    mb.push_many(16, new, [2], now=5)
    assert mb.flush_stale(-1) == []                  # nothing old enough
    (fb,) = mb.flush_stale(0)                        # only the now=0 queue
    assert fb.bucket == 8 and fb.n_real == 2
    assert fb.tokens.shape == (4, 3, 2)              # padded to microbatch
    assert float(fb.tokens[2:].sum()) == 0.0
    assert mb.pending == 1                           # now=5 queue untouched
    (fb2,) = mb.flush_stale(5)
    assert fb2.bucket == 16 and fb2.n_real == 1


def test_flush_stale_oldest_queue_first():
    mb = MicroBatcher(microbatch=4)
    mb.push_many(16, jnp.ones((1, 2, 2)), [0], now=3)
    mb.push_many(8, jnp.ones((1, 2, 2)), [1], now=1)
    out = mb.flush_stale(10)
    assert [fb.bucket for fb in out] == [8, 16]      # by age, not key


def test_drain_select_isolates_one_sessions_queues():
    """The server drains a finished session's (bucket, sid) queues without
    touching other sessions' pending frames."""
    mb = MicroBatcher(microbatch=4)
    mb.push_many((8, 0), jnp.ones((2, 3, 2)), [(0, 0), (0, 1)])
    mb.push_many((8, 1), jnp.ones((1, 3, 2)), [(1, 0)])
    out = mb.drain(select=lambda key: key[1] == 0)
    assert [fb.bucket for fb in out] == [(8, 0)]
    assert out[0].n_real == 2
    assert mb.pending == 1                           # session 1 still queued
    assert mb.pending_keys() == ((8, 1),)


# --------------------------------------------------------------------------
# bucket-ladder trimming
# --------------------------------------------------------------------------

def test_ladder_trim_drops_dead_sizes():
    lad = BucketLadder((9, 18, 27, 36))
    t = lad.trim((9, 27))
    assert t.sizes == (18, 36)
    # budgets that routed to a dropped size route up to the next survivor
    assert t.route(5) == 18 and t.route(20) == 36


def test_ladder_trim_keeps_cap_by_default():
    lad = BucketLadder((9, 18, 36))
    assert lad.trim((18, 36)).sizes == (9, 36)       # cap survives
    assert lad.trim((18, 36), keep_cap=False).sizes == (9,)
    assert lad.trim((99,)).sizes == lad.sizes        # unknown sizes ignored
    with pytest.raises(ValueError):
        lad.trim((9, 18, 36), keep_cap=False)


def test_calibrate_trim_without_sessions_is_a_no_op():
    """An empty calibration pass must not collapse the ladder to the cap
    (no sessions -> no evidence any bucket is dead)."""
    cfg = _smoke_cfg("bf16")
    srv = StreamServer(cfg, ServerConfig(microbatch=4, chunk=8,
                                         warm_start=False), n_classes=8)
    before = srv.ladder.sizes
    assert srv.calibrate_trim() == ()
    assert srv.ladder.sizes == before


def test_server_config_from_serving_preserves_server_fields():
    """from_serving on an object that already is a ServerConfig keeps its
    server-specific knobs; only the overrides change."""
    sc = ServerConfig(microbatch=8, max_wait_chunks=3, mix_streams=True,
                      mesh="off")
    out = ServerConfig.from_serving(sc, warm_start=False)
    assert (out.max_wait_chunks, out.mix_streams, out.mesh) == (3, True,
                                                                "off")
    assert out.microbatch == 8 and out.warm_start is False
    plain = ServerConfig.from_serving(ServingConfig(microbatch=2),
                                      mesh="off")
    assert plain.microbatch == 2 and plain.max_wait_chunks == 0


def test_server_calibrate_trim_shrinks_warmed_jit_set():
    cfg = _smoke_cfg("bf16")
    srv = StreamServer(cfg, ServerConfig(microbatch=4, chunk=8,
                                         warm_start=False), n_classes=8)
    for st in video_fleet(2, img_size=32, patch=8, seed=0, cut_every=16):
        srv.add_session(st, n_frames=16)
    full = set(srv.ladder.sizes)
    removed = srv.calibrate_trim()
    assert set(srv.ladder.sizes) == full - set(removed)
    assert set(srv._gather) == set(srv.ladder.sizes)  # jits dropped too
    results = srv.serve()
    for res in results.values():
        assert res.frames == 16
        assert set(res.bucket_hits) == set(srv.ladder.sizes)
        assert sum(res.bucket_hits.values()) == 16


# --------------------------------------------------------------------------
# fleet factory
# --------------------------------------------------------------------------

def test_video_fleet_streams_are_distinct_and_deterministic():
    a, b = video_fleet(2, img_size=32, patch=8, seed=7)
    assert a.seed != b.seed
    fa = a.frames_at(0, 4)["frames"]
    fb = b.frames_at(0, 4)["frames"]
    assert np.abs(fa - fb).max() > 0.5               # different scenes
    again = video_fleet(2, img_size=32, patch=8, seed=7)[0]
    np.testing.assert_array_equal(fa, again.frames_at(0, 4)["frames"])
    with pytest.raises(ValueError):
        video_fleet(0, img_size=32)


# --------------------------------------------------------------------------
# interleaved-vs-sequential bitwise parity
# --------------------------------------------------------------------------

def _parity_case(backend, attn, ffn, n_streams=2, n_frames=16, phase=4):
    """Interleaved N-stream serving must be bit-identical, per stream, to N
    sequential single-stream runs: session-pure micro-batches mean every
    encode launch contains exactly the frames a solo run would co-batch,
    so per-launch w8a8 activation absmax scopes never couple streams."""
    cfg = _smoke_cfg(backend, attn, ffn)
    sc = ServingConfig(microbatch=4, chunk=8)
    fleet = video_fleet(n_streams, img_size=32, patch=8, seed=0,
                        cut_every=16)
    seq = [ServingEngine(cfg, sc, n_classes=8, seed=0).run(
        st, n_frames=n_frames, start=phase * i)
        for i, st in enumerate(fleet)]
    srv = StreamServer(cfg, ServerConfig.from_serving(sc), n_classes=8,
                       seed=0)
    sessions = [srv.add_session(st, n_frames=n_frames, start=phase * i)
                for i, st in enumerate(fleet)]
    res = srv.serve()
    for i, s in enumerate(sessions):
        assert res[s.sid].predictions == seq[i].predictions, (
            backend, attn, ffn, i)
        assert res[s.sid].bucket_hits == seq[i].bucket_hits
        assert res[s.sid].bucket_launches == seq[i].bucket_launches
        assert res[s.sid].scored_frames == seq[i].scored_frames
        assert res[s.sid].mean_frame_uj == pytest.approx(
            seq[i].mean_frame_uj)


@pytest.mark.parametrize("backend,attn,ffn", [
    ("bf16", "", ""),
    ("photonic_sim", "", ""),
    ("photonic_pallas", "", ""),
])
def test_interleaved_matches_sequential(backend, attn, ffn):
    _parity_case(backend, attn, ffn)


@pytest.mark.slow
@pytest.mark.parametrize("backend,attn,ffn", [
    ("photonic_pallas", "flash", ""),
    ("photonic_pallas", "flash", "fused"),   # the acceptance path
    ("bf16", "xla", ""),
    ("photonic_sim", "", "xla"),
])
def test_interleaved_matches_sequential_fused(backend, attn, ffn):
    _parity_case(backend, attn, ffn, n_streams=3)


def test_warm_start_is_numerics_neutral_and_compiles_ladder():
    cfg = _smoke_cfg("photonic_sim")
    stream = VideoStream(img_size=32, patch=8, cut_every=16)
    cold_srv = StreamServer(cfg, ServerConfig(microbatch=4, chunk=8,
                                              warm_start=False), n_classes=8)
    s0 = cold_srv.add_session(stream, n_frames=16)
    cold = cold_srv.serve()[s0.sid]
    warm_srv = StreamServer(cfg, ServerConfig(microbatch=4, chunk=8),
                            n_classes=8)
    assert warm_srv.warm_s > 0                       # eager startup compile
    s1 = warm_srv.add_session(stream, n_frames=16)
    warm = warm_srv.serve()[s1.sid]
    assert warm.predictions == cold.predictions
    assert warm.bucket_hits == cold.bucket_hits


# --------------------------------------------------------------------------
# fairness + deadline
# --------------------------------------------------------------------------

def test_interleave_rounds_round_robins_backlogs():
    assert interleave_rounds([["a1", "a2", "a3"], ["b1"]]) == [
        "a1", "b1", "a2", "a3"]
    assert interleave_rounds([[], ["b1", "b2"], ["c1"]]) == [
        "b1", "c1", "b2"]
    assert interleave_rounds([]) == []
    assert interleave_rounds([[], []]) == []


def test_bursty_stream_cannot_starve_short_stream():
    """Session A has 3x the frames of B, all pinned to one bucket (two
    ready flushes per round each). While B is still serving, A's executed
    launches may lead B's by at most one scheduling round's worth — A's
    backlog never runs ahead of B's service."""
    cfg = _smoke_cfg("bf16")
    srv = StreamServer(cfg, ServerConfig(microbatch=4, chunk=8,
                                         force_bucket=1.0,
                                         warm_start=False), n_classes=8)
    a, b = video_fleet(2, img_size=32, patch=8, seed=0, cut_every=16)
    sa = srv.add_session(a, n_frames=48)
    sb = srv.add_session(b, n_frames=16)
    res = srv.serve()
    assert res[sa.sid].frames == 48 and res[sb.sid].frames == 16
    sids = [owners[0] for owners, _, _ in srv.flush_log]
    last_b = max(i for i, sid in enumerate(sids) if sid == sb.sid)
    a_before = sum(1 for sid in sids[:last_b] if sid == sa.sid)
    b_before = sum(1 for sid in sids[:last_b] if sid == sb.sid)
    # equal service rate while both live: chunk/microbatch = 2 per round
    assert a_before <= b_before + 2, (sids,)


def test_deadline_flush_bounds_wait_without_leaking_padding():
    """max_wait_chunks pad-flushes partial micro-batches; padded rows must
    never surface in accounting (frames, energy) or predictions. Routing
    happens before batching, so the modeled per-frame energy is identical
    to the no-deadline run even though launch compositions differ."""
    cfg = _smoke_cfg("bf16")
    stream = VideoStream(img_size=32, patch=8, seed=2, cut_every=16)

    def run(max_wait):
        # chunk (3) < microbatch (8): arrivals alone never fill a batch in
        # one round, so partial queues survive rounds and the deadline has
        # something to flush mid-stream
        srv = StreamServer(cfg, ServerConfig(
            microbatch=8, chunk=3, force_bucket=1.0,
            max_wait_chunks=max_wait, warm_start=False), n_classes=8)
        s = srv.add_session(stream, n_frames=12)
        return srv.serve()[s.sid], srv

    free, srv_free = run(0)
    tight, srv_tight = run(1)
    for res in (free, tight):
        assert res.frames == 12
        assert sorted(res.predictions) == list(range(12))
        assert sum(res.bucket_hits.values()) == 12
    assert tight.bucket_hits == free.bucket_hits     # routing unchanged
    assert tight.mean_frame_uj == pytest.approx(free.mean_frame_uj)
    # the deadline fired mid-stream: more short (padded) launches than the
    # no-deadline run's single end-of-stream drain...
    tight_partial = [n for _, _, n in srv_tight.flush_log if n < 8]
    free_partial = [n for _, _, n in srv_free.flush_log if n < 8]
    assert len(tight_partial) > len(free_partial) >= 1
    # ...and a frame queued at round r is served within max_wait rounds:
    # no launch ever carries more than max_wait+1 rounds' worth of arrivals
    assert max(n for _, _, n in srv_tight.flush_log) <= 2 * 3


def test_mid_serve_failure_poisons_half_served_sessions():
    """A serve() that dies mid-stream must not leave resumable-looking
    sessions behind: their accounting is partial, and re-opening them
    would re-ingest from frame 0 and double-count. They are abandoned;
    fresh sessions serve cleanly afterwards."""
    cfg = _smoke_cfg("bf16")
    srv = StreamServer(cfg, ServerConfig(microbatch=4, chunk=8,
                                         warm_start=False), n_classes=8)
    stream = VideoStream(img_size=32, patch=8, cut_every=16)
    s = srv.add_session(stream, n_frames=16)

    def boom(fb, by_sid):
        raise RuntimeError("encode died")

    real_finish = srv._finish
    srv._finish = boom
    with pytest.raises(RuntimeError, match="encode died"):
        srv.serve()
    assert s.finished                       # poisoned, never re-served
    assert srv._sessions == []
    srv._finish = real_finish
    s2 = srv.add_session(stream, n_frames=8)
    res = srv.serve()
    assert list(res) == [s2.sid]
    assert res[s2.sid].frames == 8


# --------------------------------------------------------------------------
# mixed-stream micro-batches (opt-in)
# --------------------------------------------------------------------------

def test_mix_streams_fills_across_sessions():
    """mix_streams=True genuinely co-batches sessions (fewer launches than
    session-pure) and still serves every frame exactly once. On the float
    backend each row's result is independent of its co-batched rows, so
    predictions stay bit-identical to sequential runs even when mixed."""
    cfg = _smoke_cfg("bf16")
    sc = ServingConfig(microbatch=4, chunk=8)
    fleet = video_fleet(2, img_size=32, patch=8, seed=3, cut_every=16)
    seq = [ServingEngine(cfg, sc, n_classes=8, seed=0).run(st, n_frames=16)
           for st in fleet]
    srv = StreamServer(cfg, ServerConfig.from_serving(
        sc, mix_streams=True, warm_start=False), n_classes=8, seed=0)
    sessions = [srv.add_session(st, n_frames=16) for st in fleet]
    res = srv.serve()
    assert any(len(owners) > 1 for owners, _, _ in srv.flush_log)
    pure_launches = sum(sum(r.bucket_launches.values()) for r in seq)
    assert len(srv.flush_log) <= pure_launches
    for i, s in enumerate(sessions):
        assert res[s.sid].frames == 16
        assert res[s.sid].predictions == seq[i].predictions


# --------------------------------------------------------------------------
# mesh-sharded encode (forced multi-device CPU host, subprocess)
# --------------------------------------------------------------------------

_MESH_SCRIPT = """
import json, sys
from repro.data.pipeline import video_fleet
from repro.serving.engine import _smoke_cfg
from repro.serving.server import ServerConfig, StreamServer
import jax
mode = sys.argv[1]
cfg = _smoke_cfg("photonic_sim")
srv = StreamServer(cfg, ServerConfig(microbatch=4, chunk=8, mesh=mode,
                                     warm_start=False), n_classes=8)
if mode == "auto":
    assert srv.mesh is not None and len(jax.devices()) == 2, jax.devices()
else:
    assert srv.mesh is None
sessions = [srv.add_session(st, n_frames=16)
            for st in video_fleet(2, img_size=32, patch=8, seed=0,
                                  cut_every=16)]
res = srv.serve()
print(json.dumps({str(s.sid): res[s.sid].predictions for s in sessions}))
"""


@pytest.mark.slow
def test_mesh_sharded_encode_matches_single_device():
    """With XLA forced to expose 2 host devices, the server shards the
    encode batch axis over the ("data",) mesh; predictions must match the
    single-device run exactly (integer accumulates are placement-
    invariant; per-frame float epilogues are row-local)."""
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu",
               XLA_FLAGS=(os.environ.get("XLA_FLAGS", "") +
                          " --xla_force_host_platform_device_count=2"))
    outs = {}
    for mode in ("auto", "off"):
        proc = subprocess.run(
            [sys.executable, "-c", _MESH_SCRIPT, mode],
            capture_output=True, text=True, timeout=600, env=env,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
        assert proc.returncode == 0, proc.stderr[-2000:]
        outs[mode] = json.loads(proc.stdout.strip().splitlines()[-1])
    assert outs["auto"] == outs["off"]


_MODEL_MESH_SCRIPT = """
import json, sys
import jax
from repro.data.pipeline import video_fleet
from repro.serving.engine import _smoke_cfg
from repro.serving.server import ServerConfig, StreamServer

shards = int(sys.argv[1])
cfg = _smoke_cfg("photonic_pallas", "flash", "fused")
srv = StreamServer(cfg, ServerConfig(microbatch=4, chunk=8,
                                     warm_start=False,
                                     mesh="auto" if shards else "off",
                                     model_shards=shards, one_shape=True),
                   n_classes=8)
if shards:
    assert srv.mesh is not None and len(jax.devices()) == 4, jax.devices()
    assert tuple(srv.mesh.axis_names) == ("data", "model"), srv.mesh
else:
    assert srv.mesh is None
sessions = [srv.add_session(st, n_frames=16)
            for st in video_fleet(2, img_size=32, patch=8, seed=0,
                                  cut_every=16)]
res = srv.serve()
from repro.models.sharded_encoder import sharded_encoder_cache_size
print(json.dumps({
    "predictions": {str(s.sid): res[s.sid].predictions for s in sessions},
    "sharded_jits": sharded_encoder_cache_size(),
}))
"""


@pytest.mark.slow
def test_model_sharded_fused_encode_matches_single_device():
    """The tentpole contract: the fully-fused serving combo
    (photonic_pallas + flash + fused) under model_shards=2 on a forced
    4-device 2-D ("data", "model") mesh predicts bitwise-identically to
    the unsharded fused path, and the sharded jit cache actually engages
    (a silent fallback to the unsharded encoder would make the parity
    assertion vacuous)."""
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu",
               XLA_FLAGS=(os.environ.get("XLA_FLAGS", "") +
                          " --xla_force_host_platform_device_count=4"))
    outs = {}
    for shards in ("2", "0"):
        proc = subprocess.run(
            [sys.executable, "-c", _MODEL_MESH_SCRIPT, shards],
            capture_output=True, text=True, timeout=600, env=env,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
        assert proc.returncode == 0, proc.stderr[-2000:]
        outs[shards] = json.loads(proc.stdout.strip().splitlines()[-1])
    assert outs["2"]["predictions"] == outs["0"]["predictions"]
    assert outs["2"]["sharded_jits"] > 0
    assert outs["0"]["sharded_jits"] == 0
