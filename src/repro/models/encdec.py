"""Encoder-decoder transformer (Whisper-style backbone).

The conv/mel frontend is a STUB per the assignment: ``input_specs`` supplies
precomputed frame embeddings (B, enc_frames, d_frontend). The encoder is a
bidirectional transformer over frames (learned positional embedding); the
decoder is causal with cross-attention to the encoder output every layer.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard
from repro.models import ffn as ffn_mod
from repro.models.attention import (blockwise_attention, decode_attention,
                                    full_attention, update_kv_cache)
from repro.models.layers import (ExecPolicy, apply_rope, embedding_lookup,
                                 he_init, linear, rmsnorm, rope)
from repro.models.transformer import (attention_logical_axes, attn_decode,
                                      attn_forward, init_attention)

__all__ = ["init_encdec", "encdec_logical_axes", "forward_encdec",
           "encode", "encdec_cache_spec", "decode_step_encdec"]


def _init_cross(key, cfg, dtype):
    return init_attention(key, cfg, dtype)


def init_encdec(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> dict:
    d = cfg.d_model
    dfr = cfg.d_frontend or d
    ks = jax.random.split(key, 8)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": jnp.ones((d,), dtype),
                "attn": init_attention(k1, cfg, dtype),
                "ln2": jnp.ones((d,), dtype),
                "ffn": ffn_mod.init_mlp(k2, d, cfg.d_ff, dtype)}

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"ln1": jnp.ones((d,), dtype),
                "attn": init_attention(k1, cfg, dtype),
                "lnx": jnp.ones((d,), dtype),
                "xattn": _init_cross(k2, cfg, dtype),
                "ln2": jnp.ones((d,), dtype),
                "ffn": ffn_mod.init_mlp(k3, d, cfg.d_ff, dtype)}

    return {
        "frontend_proj": he_init(ks[0], (dfr, d), dtype),
        "enc_pos": (jax.random.normal(ks[1], (cfg.enc_frames, d), jnp.float32)
                    * 0.02).astype(dtype),
        "enc_blocks": jax.vmap(enc_layer)(jax.random.split(ks[2], cfg.enc_layers)),
        "enc_ln": jnp.ones((d,), dtype),
        "embed": (jax.random.normal(ks[3], (cfg.vocab, d), jnp.float32)
                  * 0.02).astype(dtype),
        "dec_blocks": jax.vmap(dec_layer)(jax.random.split(ks[4], cfg.n_layers)),
        "final_ln": jnp.ones((d,), dtype),
        "lm_head": he_init(ks[5], (d, cfg.vocab), dtype),
    }


def encdec_logical_axes(cfg: ArchConfig) -> dict:
    from repro.models.transformer import _tree_prepend_axis
    enc_l = {"ln1": (None,), "attn": attention_logical_axes(cfg),
             "ln2": (None,), "ffn": ffn_mod.mlp_logical_axes()}
    dec_l = {"ln1": (None,), "attn": attention_logical_axes(cfg),
             "lnx": (None,), "xattn": attention_logical_axes(cfg),
             "ln2": (None,), "ffn": ffn_mod.mlp_logical_axes()}
    return {"frontend_proj": (None, "p_embed"),
            "enc_pos": (None, "p_embed"),
            "enc_blocks": _tree_prepend_axis(enc_l),
            "enc_ln": (None,),
            "embed": ("p_vocab", "p_embed"),
            "dec_blocks": _tree_prepend_axis(dec_l),
            "final_ln": (None,),
            "lm_head": ("p_embed", "p_vocab")}


def _cross_attn(p, x, enc_kv, cfg, policy):
    """Cross attention: q from x, k/v precomputed from encoder output."""
    b, s, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.kv_heads, cfg.head_dim
    q = linear(x, p["wq"], p.get("bq"), policy).reshape(b, s, h, hd)
    k, v = enc_kv
    o = full_attention(q, k, v, causal=False)
    return linear(o.reshape(b, s, h * hd), p["wo"], policy=policy)


def _enc_kv(p, enc_out, cfg, policy):
    b, t, _ = enc_out.shape
    hkv, hd = cfg.kv_heads, cfg.head_dim
    k = linear(enc_out, p["wk"], p.get("bk"), policy).reshape(b, t, hkv, hd)
    v = linear(enc_out, p["wv"], p.get("bv"), policy).reshape(b, t, hkv, hd)
    return k, v


def encode(params: dict, frames: jnp.ndarray, cfg: ArchConfig,
           policy: ExecPolicy | None = None) -> jnp.ndarray:
    """frames (B, T, d_frontend) -> encoder states (B, T, d)."""
    policy = policy or ExecPolicy.from_cfg(cfg)
    x = linear(frames, params["frontend_proj"], policy=policy)
    x = x + params["enc_pos"][None, : x.shape[1]]
    x = shard(x, "batch", "seq", "embed")

    def body(carry, lp):
        h = rmsnorm(carry, lp["ln1"], cfg.norm_eps)
        b, s, _ = h.shape
        hh, hkv, hd = cfg.n_heads, cfg.kv_heads, cfg.head_dim
        q = linear(h, lp["attn"]["wq"], lp["attn"].get("bq"), policy) \
            .reshape(b, s, hh, hd)
        k = linear(h, lp["attn"]["wk"], lp["attn"].get("bk"), policy) \
            .reshape(b, s, hkv, hd)
        v = linear(h, lp["attn"]["wv"], lp["attn"].get("bv"), policy) \
            .reshape(b, s, hkv, hd)
        o = blockwise_attention(q, k, v, causal=False,
                                block_q=cfg.attn_block_q,
                                block_kv=cfg.attn_block_kv)
        carry = carry + linear(o.reshape(b, s, hh * hd), lp["attn"]["wo"],
                               policy=policy)
        carry = carry + ffn_mod.mlp(lp["ffn"],
                                    rmsnorm(carry, lp["ln2"], cfg.norm_eps),
                                    policy)
        return shard(carry, "batch", "seq", "embed"), None

    fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(fn, x, params["enc_blocks"])
    return rmsnorm(x, params["enc_ln"], cfg.norm_eps)


def forward_encdec(params: dict, frames: jnp.ndarray, tokens: jnp.ndarray,
                   cfg: ArchConfig, policy: ExecPolicy | None = None):
    """Train/prefill forward. Returns (logits (B, S, V), aux=0)."""
    policy = policy or ExecPolicy.from_cfg(cfg)
    enc_out = encode(params, frames, cfg, policy)
    x = embedding_lookup(params["embed"], tokens)
    x = shard(x, "batch", "seq", "embed")

    def body(carry, lp):
        h, _ = attn_forward(lp["attn"], rmsnorm(carry, lp["ln1"], cfg.norm_eps),
                            cfg, policy)
        carry = carry + h
        kv = _enc_kv(lp["xattn"], enc_out, cfg, policy)
        carry = carry + _cross_attn(lp["xattn"],
                                    rmsnorm(carry, lp["lnx"], cfg.norm_eps),
                                    kv, cfg, policy)
        carry = carry + ffn_mod.mlp(lp["ffn"],
                                    rmsnorm(carry, lp["ln2"], cfg.norm_eps),
                                    policy)
        return shard(carry, "batch", "seq", "embed"), None

    fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(fn, x, params["dec_blocks"])
    x = rmsnorm(x, params["final_ln"], cfg.norm_eps)
    logits = linear(x, params["lm_head"], policy=policy)
    return shard(logits, "batch", "seq", "vocab"), jnp.zeros((), jnp.float32)


def encdec_cache_spec(cfg: ArchConfig, batch: int, seq_len: int,
                      dtype=jnp.bfloat16):
    hkv, hd = cfg.kv_heads, cfg.head_dim
    n_l, t = cfg.n_layers, cfg.enc_frames
    shapes = {"k": ((n_l, batch, seq_len, hkv, hd), dtype),
              "v": ((n_l, batch, seq_len, hkv, hd), dtype),
              "xk": ((n_l, batch, t, hkv, hd), dtype),
              "xv": ((n_l, batch, t, hkv, hd), dtype)}
    axes = {"k": ("p_layers", "batch", "kv_seq", None, None),
            "v": ("p_layers", "batch", "kv_seq", None, None),
            "xk": ("p_layers", "batch", None, None, None),
            "xv": ("p_layers", "batch", None, None, None)}
    return shapes, axes


def decode_step_encdec(params: dict, cache: dict, tokens: jnp.ndarray, pos,
                       cfg: ArchConfig, policy: ExecPolicy | None = None):
    """Decoder-only step against self KV cache + precomputed cross KV."""
    policy = policy or ExecPolicy.from_cfg(cfg, training=False)
    x = embedding_lookup(params["embed"], tokens)

    def body(carry, xs):
        lp, ck, cv, xk, xv = xs
        h = rmsnorm(carry, lp["ln1"], cfg.norm_eps)
        o, ck, cv = attn_decode(lp["attn"], h, ck, cv, pos, cfg, policy)
        carry = carry + o
        hx = rmsnorm(carry, lp["lnx"], cfg.norm_eps)
        carry = carry + _cross_attn(lp["xattn"], hx, (xk, xv), cfg, policy)
        carry = carry + ffn_mod.mlp(lp["ffn"],
                                    rmsnorm(carry, lp["ln2"], cfg.norm_eps),
                                    policy)
        return carry, (ck, cv)

    x, (k2, v2) = jax.lax.scan(body, x, (params["dec_blocks"], cache["k"],
                                         cache["v"], cache["xk"], cache["xv"]))
    x = rmsnorm(x, params["final_ln"], cfg.norm_eps)
    logits = linear(x, params["lm_head"], policy=policy)[:, 0]
    return logits, {"k": k2, "v": v2, "xk": cache["xk"], "xv": cache["xv"]}
