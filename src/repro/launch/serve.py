"""Serving driver: batched prefill + token-by-token decode on host devices.

Demonstrates the inference path end to end (the dry-run lowers the same
``serve_step``): prefill the prompt, write K/V (or recurrent state) into
the cache, then decode tokens with the one-token step. On a production
pod the KV cache sits seq-sharded over the "model" axis (flash-decoding);
on the host mesh the same code path runs with whatever axes exist.

Usage:
    python -m repro.launch.serve --arch qwen2-1.5b --smoke \\
        --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig, smoke_variant
from repro.configs.registry import get_config
from repro.core.backend import available_backends, prepare_params
from repro.distributed.sharding import current_ctx, use_sharding
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_serve_step
from repro.models import api as model_api
from repro.models.layers import ExecPolicy

__all__ = ["init_cache", "prefill_into_cache", "generate", "main"]


def init_cache(cfg: ArchConfig, batch: int, seq_len: int):
    shapes, _ = model_api.cache_axes_spec(cfg, batch, seq_len)
    return {k: jnp.zeros(s, d) for k, (s, d) in shapes.items()}


@functools.lru_cache(maxsize=8)
def _prefill_scan(cfg: ArchConfig):
    """One jitted lax.scan over prompt positions via the decode step.

    The previous Python loop dispatched (and on the first call *traced*)
    ``decode_fn`` once per token — prompt-length many XLA launches that
    dominated smoke-serve wall time. The scan traces the step once and runs
    the whole prefill as a single device program; it stays family-agnostic
    because the body is still ``model_api.decode_fn``.
    """

    def run(params, cache, prompt):
        toks = jnp.swapaxes(prompt, 0, 1)[:, :, None]      # (S, B, 1)
        positions = jnp.arange(prompt.shape[1], dtype=jnp.int32)

        def body(cache, inp):
            tok, pos = inp
            logits, cache = model_api.decode_fn(params, cache, tok, pos, cfg)
            return cache, logits

        cache, logits = jax.lax.scan(body, cache, (toks, positions))
        return logits[-1], cache

    return jax.jit(run)


def prefill_into_cache(params, cache, prompt, cfg: ArchConfig,
                       extras: dict | None = None):
    """Prefill the prompt into the decode cache (jitted scan; correct for
    every family — a fused prefill that emits the cache in one pass is the
    production path, the scanned decode step keeps this driver
    family-agnostic). Returns (last-position logits, filled cache)."""
    return _prefill_scan(cfg)(params, cache, prompt)


def generate(params, cache, prompt, n_tokens: int, cfg: ArchConfig,
             greedy: bool = True, seed: int = 0):
    """Returns (generated (B, n_tokens) i32, tokens/s)."""
    b, plen = prompt.shape
    logits, cache = prefill_into_cache(params, cache, prompt, cfg)
    step_fn = jax.jit(
        lambda p, c, t, pos: model_api.decode_fn(p, c, t, pos, cfg))
    out = []
    key = jax.random.PRNGKey(seed)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t0 = time.time()
    for i in range(n_tokens):
        out.append(tok)
        logits, cache = step_fn(params, cache, tok, jnp.int32(plen + i))
        if greedy:
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        else:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits)[:, None].astype(
                jnp.int32)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    return jnp.concatenate(out, axis=1), (b * n_tokens) / dt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--data-par", type=int, default=1)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--backend", default="",
                    help=f"matmul backend ({', '.join(available_backends())}"
                         "; empty = resolve from config flags)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    if args.backend:
        if args.backend not in available_backends():
            raise SystemExit(f"unknown backend {args.backend!r}; "
                             f"choose from {available_backends()}")
        cfg = cfg.with_(matmul_backend=args.backend)
    if not model_api.supports_decode(cfg):
        raise SystemExit(f"{args.arch} has no decode step")

    policy = ExecPolicy.from_cfg(cfg, training=False)
    mesh = make_host_mesh(args.data_par, args.model_par)
    with mesh, use_sharding(mesh):
        key = jax.random.PRNGKey(0)
        params = model_api.init_model(key, cfg)
        if policy.is_photonic():
            # quantize-once weight cache: tune every matmul weight before
            # serving so the per-token path does only activation quant +
            # integer matmul + dequant (embeddings/norms stay fp).
            params = prepare_params(params, bits=cfg.quant_bits or 8)
            print(f"[serve] backend={policy.resolve_backend()} "
                  "(weights pre-quantized once)")
        cache = init_cache(cfg, args.batch, args.cache_len)
        prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                    cfg.vocab, jnp.int32)
        toks, tps = generate(params, cache, prompt, args.gen, cfg)
    print(f"[serve] generated {toks.shape} tokens at {tps:.1f} tok/s "
          f"(batch {args.batch})")
    print("[serve] first sequence:", np.asarray(toks[0])[:16])


if __name__ == "__main__":
    main()
