"""MR device model tests (paper §IV "MR Resolution Analysis")."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.noise import (_FPV_FOLD, DriftState, MRConfig, NoiseSpec,
                              crosstalk_matrix, drifted_noise_floor,
                              mr_detune_gain, next_call_keys, noise_power,
                              noise_scope, required_q_factor,
                              resolution_bits, scope_salt,
                              transmission_error, wavelength_grid)


def test_grid_centered():
    cfg = MRConfig()
    lam = wavelength_grid(cfg)
    assert lam.shape == (32,)
    np.testing.assert_allclose(float(lam.mean()), cfg.center_nm, atol=1e-3)


def test_crosstalk_matrix_properties():
    phi = crosstalk_matrix(MRConfig())
    p = np.asarray(phi)
    assert p.shape == (32, 32)
    assert np.all(np.diag(p) == 0)           # own channel is not noise
    assert np.all(p >= 0) and np.all(p < 1)
    # nearest neighbours dominate
    assert p[0, 1] > p[0, 2] > p[0, 3]


def test_noise_power_worst_case_at_full_power():
    cfg = MRConfig()
    pn_full = noise_power(cfg)
    pn_half = noise_power(cfg, jnp.full((32,), 0.5))
    assert float(pn_half.max()) < float(pn_full.max())


def test_resolution_monotone_in_q():
    bits = [resolution_bits(MRConfig(q_factor=q))
            for q in (1000, 3000, 5000, 10000)]
    assert bits == sorted(bits)


def test_paper_claim_8bit_needs_q5000():
    """Paper: 'achieving at least 8-bit resolution requires MRs with a
    Q-factor of about 5000' — the calibrated grid reproduces this."""
    assert resolution_bits(MRConfig(q_factor=5000.0)) >= 8.0
    assert resolution_bits(MRConfig(q_factor=2000.0)) < 8.0
    q_min = required_q_factor(8.0)
    assert 3000 < q_min < 5100, q_min


def test_transmission_error_mean_one():
    key = jax.random.PRNGKey(0)
    m = transmission_error(key, (4096,), MRConfig())
    assert abs(float(m.mean()) - 1.0) < 1e-2
    # bounded by the crosstalk floor
    floor = 2.0 ** (-resolution_bits(MRConfig()))
    assert float(jnp.abs(m - 1.0).max()) <= floor + 1e-6


def test_transmission_error_fpv_widens():
    key = jax.random.PRNGKey(0)
    base = transmission_error(key, (4096,), MRConfig())
    fpv = transmission_error(key, (4096,), MRConfig(), fpv_sigma=0.05)
    assert float(jnp.std(fpv)) > float(jnp.std(base))


def test_fpv_key_independence_regression():
    """Regression for the PRNG key-reuse bug: the FPV gaussian was drawn
    from ``jax.random.split(key)[0]`` of the key the crosstalk uniform had
    *already consumed* — correlating the two components. The fix derives
    the FPV subkey by ``fold_in`` so (a) the fpv_sigma=0 path is bitwise
    unchanged, (b) the FPV sample changed vs the buggy derivation, and
    (c) the components decorrelate."""
    key = jax.random.PRNGKey(7)
    cfg = MRConfig()
    shape = (8192,)
    floor = 2.0 ** (-resolution_bits(cfg))

    # (a) fpv_sigma=0: exactly the historical single-draw formula
    base = transmission_error(key, shape, cfg)
    expect = 1.0 + jax.random.uniform(key, shape, minval=-floor,
                                      maxval=floor)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(expect))

    # the derived key is actually distinct from the consumed one
    fkey = jax.random.fold_in(key, _FPV_FOLD)
    assert not np.array_equal(np.asarray(fkey), np.asarray(key))
    assert not np.array_equal(np.asarray(fkey),
                              np.asarray(jax.random.split(key)[0]))

    # (b) the FPV component matches the fold derivation, not the buggy one
    sigma = 0.05
    fpv = transmission_error(key, shape, cfg, fpv_sigma=sigma)
    comp = np.asarray(fpv) / np.asarray(base) - 1.0
    want = sigma * jax.random.normal(fkey, shape)
    np.testing.assert_allclose(comp, np.asarray(want), atol=1e-6)
    buggy = sigma * jax.random.normal(jax.random.split(key)[0], shape)
    assert float(np.abs(comp - np.asarray(buggy)).max()) > 1e-3

    # (c) decorrelated from the crosstalk uniform
    u = np.asarray(base) - 1.0
    corr = float(np.corrcoef(u, comp)[0, 1])
    assert abs(corr) < 0.05, corr


def test_fpv_explicit_key_overrides_fold():
    """A device-static ``fpv_key`` pins the FPV pattern regardless of the
    per-call draw key — the chip's fabrication does not change per frame."""
    cfg = MRConfig()
    fkey = jax.random.PRNGKey(42)
    a = transmission_error(jax.random.PRNGKey(0), (512,), cfg,
                           fpv_sigma=0.05, fpv_key=fkey)
    b = transmission_error(jax.random.PRNGKey(1), (512,), cfg,
                           fpv_sigma=0.05, fpv_key=fkey)
    ca = np.asarray(a) / np.asarray(transmission_error(
        jax.random.PRNGKey(0), (512,), cfg)) - 1.0
    cb = np.asarray(b) / np.asarray(transmission_error(
        jax.random.PRNGKey(1), (512,), cfg)) - 1.0
    np.testing.assert_allclose(ca, cb, atol=1e-6)


def test_mr_detune_gain_lorentzian():
    cfg = MRConfig()
    assert float(mr_detune_gain(cfg, 0.0)) == 1.0
    gains = [float(mr_detune_gain(cfg, d)) for d in (0.05, 0.1, 0.2, 0.5)]
    assert gains == sorted(gains, reverse=True)
    # half-gain at one linewidth delta = lambda/(2Q) ~= 0.155 nm at Q=5000
    delta = cfg.center_nm / (2.0 * cfg.q_factor)
    np.testing.assert_allclose(float(mr_detune_gain(cfg, delta)), 0.5,
                               rtol=1e-6)
    # 0.5 nm (paper's catastrophic regime) kills most of the transmission
    assert gains[-1] < 0.1


def test_drifted_noise_floor_matches_static_at_zero():
    cfg = MRConfig()
    static = 2.0 ** (-resolution_bits(cfg))
    np.testing.assert_allclose(float(drifted_noise_floor(cfg, 0.0)), static,
                               rtol=1e-6)
    f1 = float(drifted_noise_floor(cfg, 1.0))
    f2 = float(drifted_noise_floor(cfg, 2.0))
    assert static < f1 < f2


def test_noise_spec_hashable_and_jit_safe():
    a = NoiseSpec()
    b = NoiseSpec()
    assert hash(a) == hash(b) and a == b
    assert hash(NoiseSpec(q_factor=2000.0)) != hash(a) or \
        NoiseSpec(q_factor=2000.0) != a
    assert a.mr().q_factor == a.q_factor


def test_drift_state_advance_and_reset():
    spec = NoiseSpec(drift_rate_nm=0.01)
    st = DriftState.init(0)
    assert int(st.frame) == 0 and float(st.drift_nm) == 0.0
    st2 = st.advance(spec, 8)
    assert int(st2.frame) == 8
    np.testing.assert_allclose(float(st2.drift_nm), 0.08, rtol=1e-5)
    st3 = st2.reset_drift()
    assert float(st3.drift_nm) == 0.0 and int(st3.frame) == 8
    # registered pytree: flattens to scalars (jit-argument safe)
    leaves = jax.tree_util.tree_leaves(st2)
    assert len(leaves) == 3


def test_next_call_keys_requires_scope_and_is_per_call():
    spec = NoiseSpec()
    with pytest.raises(RuntimeError, match="no noise scope"):
        next_call_keys(spec)
    with noise_scope(DriftState.init(0)):
        k1, f1, d = next_call_keys(spec)
        k2, f2, _ = next_call_keys(spec)
        assert not np.array_equal(np.asarray(k1), np.asarray(k2))
        assert not np.array_equal(np.asarray(f1), np.asarray(f2))
        with scope_salt(3):
            k3, _, _ = next_call_keys(spec)
        assert not np.array_equal(np.asarray(k2), np.asarray(k3))
    # a fresh scope over the same state replays the same key sequence
    with noise_scope(DriftState.init(0)):
        k1b, f1b, _ = next_call_keys(spec)
    np.testing.assert_array_equal(np.asarray(k1), np.asarray(k1b))
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f1b))


def test_frame_advance_changes_draw_key_not_fpv_key():
    """Time moves the noise draws but never the fabrication pattern."""
    spec = NoiseSpec(drift_rate_nm=0.0)
    st = DriftState.init(0)
    with noise_scope(st):
        k0, f0, _ = next_call_keys(spec)
    with noise_scope(st.advance(spec, 1)):
        k1, f1, _ = next_call_keys(spec)
    assert not np.array_equal(np.asarray(k0), np.asarray(k1))
    np.testing.assert_array_equal(np.asarray(f0), np.asarray(f1))
