"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) d_ff=768/expert
vocab=151936, 128 experts top-8 (hf:Qwen/Qwen3-30B-A3B)."""

from repro.configs.base import ArchConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-moe-30b-a3b", family="moe",
        n_layers=48, d_model=2048, n_heads=32, kv_heads=4,
        d_ff=768, vocab=151936,
        n_experts=128, top_k=8, shared_experts=0, first_dense_layers=0,
        capacity_factor=1.25, moe_groups=16,
        rope_theta=1000000.0,
        microbatch_steps=1,
    )
