"""Pallas TPU kernel: photonic-constrained w8a8 integer MatMul.

TPU adaptation of the Opto-ViT optical core (DESIGN.md §2). The optical
core multiplies a 32-element input chunk (wavelength channels) against a
32x64 MR weight tile per cycle and accumulates chunk partials
electronically (paper Fig. 6). On TPU the analogous schedule is a blocked
int8 x int8 -> int32 MXU matmul whose K-grid walk plays the role of the
wavelength-chunk walk:

  * block shapes are multiples of the photonic (32, 64) tile, aligned up
    to the MXU native 128 lane width: bm x bk x bn = 128 x 128 x 128
    (one K-block = 4 wavelength chunks; one N-block = 2 arm groups),
  * accumulation is int32 in VMEM scratch across the K grid dimension
    (the electronic partial-sum accumulate),
  * the dequant epilogue applies the per-tensor activation scale and
    per-output-channel weight scale on the last K step (the ADC + scale
    restore), writing f32.

Numerics contract: the integer accumulate matches kernels/ref.py::
photonic_matmul_ref exactly; the f32 dequant epilogue may differ by XLA
reassociation (<= 2 ulp). Validated under interpret=True on CPU for
shape/dtype sweeps in tests/test_kernels_photonic.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["photonic_matmul_kernel", "photonic_matmul_int8"]

# photonic tile geometry (paper Fig. 3b): 32 wavelengths x 64 arms
WAVELENGTHS = 32
ARMS = 64


def photonic_matmul_kernel(x_ref, w_ref, sx_ref, sw_ref, o_ref, acc_ref):
    """Grid (M/bm, N/bn, K/bk). x int8 (bm,bk); w int8 (bk,bn);
    sx (1,1) f32; sw (1,bn) f32; o f32 (bm,bn); acc int32 scratch."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # int8 x int8 -> int32 (MXU integer path). The K-block walk is the
    # wavelength-chunk accumulate of paper Fig. 6.
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _epilogue():
        # dequant: per-tensor activation scale x per-channel weight scale.
        o_ref[...] = (acc_ref[...].astype(jnp.float32)
                      * sx_ref[0, 0] * sw_ref[0, :][None, :])


def photonic_matmul_int8(xq: jax.Array, wq: jax.Array, sx: jax.Array,
                         sw: jax.Array, *, bm: int = 128, bn: int = 128,
                         bk: int = 128, interpret: bool = True) -> jax.Array:
    """xq (M,K) int8, wq (K,N) int8, sx () f32, sw (N,) f32 -> (M,N) f32.

    M/K/N must be multiples of the block sizes (callers pad; ops.py does).
    ``interpret=True`` executes the kernel body in Python on CPU — the
    validation mode for this host; on a real TPU pass interpret=False.
    """
    m, k = xq.shape
    k2, n = wq.shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0, \
        (xq.shape, wq.shape, bm, bn, bk)
    assert bk % WAVELENGTHS == 0 and bn % ARMS == 0, (bk, bn)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        photonic_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
            pl.BlockSpec((1, 1), lambda i, j, l: (0, 0)),
            pl.BlockSpec((1, bn), lambda i, j, l: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(xq, wq, sx.reshape(1, 1), sw.reshape(1, n))
