"""qwen2.5-3b [dense]: 36L d_model=2048 16H (GQA kv=2) d_ff=11008
vocab=151936, QKV bias (hf:Qwen/Qwen2.5)."""

from repro.configs.base import ArchConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        name="qwen2.5-3b", family="dense",
        n_layers=36, d_model=2048, n_heads=16, kv_heads=2,
        d_ff=11008, vocab=151936,
        qkv_bias=True, rope_theta=1000000.0,
        tie_embeddings=True,
        microbatch_steps=1,
    )
