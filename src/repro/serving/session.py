"""Per-stream session state for the multi-stream serving server.

The single-stream engine conflated two kinds of state: *shared* resources
(the prepared weight cache, the per-bucket jit ladder, the micro-batch
scheduler) and *per-stream* bookkeeping (the temporal mask cache, the
deferred-prediction list, the energy accounting). ``StreamSession`` owns
exactly the second kind — everything whose lifetime is one stream:

  * ``TemporalMaskCache`` — mask reuse is a *temporal* property of one
    camera's frames; streams must never share a reference frame;
  * ``StreamAccounting`` + ``BucketHistogram`` — per-stream KFPS/W and
    bucket telemetry (the Table-4 metric is per camera);
  * the deferred-prediction list — ``(frame_idx, logits-argmax)`` pairs
    held as device arrays until end of stream so host bookkeeping overlaps
    device encodes (async dispatch), then materialized once;
  * the ingest iterator (chunked, double-buffered to device) with the
    stream's own ``start`` phase.

Sessions are driven by ``repro.serving.server.StreamServer`` — they hold no
jits and no parameters. ``ServingConfig`` and ``StreamResult`` live here
(not in ``engine``) because both the server and the single-session engine
shim consume them; ``engine`` re-exports for compatibility.
"""

from __future__ import annotations

from dataclasses import asdict, dataclass, field, is_dataclass

import numpy as np

from repro.data.pipeline import VideoStream, prefetch_to_device
from repro.serving.accounting import StreamAccounting
from repro.serving.buckets import BucketHistogram, BucketLadder
from repro.serving.mask_cache import TemporalMaskCache

__all__ = ["ServingConfig", "StreamResult", "StreamSession"]


@dataclass(frozen=True)
class ServingConfig:
    """Engine knobs (the ladder fractions are quantized to patch counts)."""

    bucket_fractions: tuple[float, ...] = (0.25, 0.5, 0.75, 1.0)
    microbatch: int = 4
    chunk: int = 8               # frames per ingest transfer
    mask_refresh: int = 8        # re-score MGNet at least every k frames
    delta_threshold: float = 0.15
    prefetch_depth: int = 2
    report_every: int = 4        # live metrics cadence (chunks)
    force_bucket: float = 0.0    # > 0: pin every frame's budget to this
    #                              fraction of N (the paper's fixed
    #                              keep-ratio inference; also the controlled
    #                              operating point for skip-ratio benchmarks)
    one_shape: bool = False      # fixed-sensor-buffer mode: every encode is
    #                              (microbatch, ladder.cap, d) with the
    #                              score-ordered tokens and a static packed
    #                              kept-count (kv_len) per bucket — one
    #                              token shape, |ladder| kv_len-specialized
    #                              jits; the flash attention backend skips
    #                              the pruned tail's score FLOPs


@dataclass
class StreamResult:
    """What one stream served, measured two ways: host wall clock
    (functional sim throughput) and accelerator model (KFPS/W)."""

    frames: int = 0
    wall_s: float = 0.0
    scored_frames: int = 0
    reused_frames: int = 0
    bucket_hits: dict = field(default_factory=dict)
    bucket_launches: dict = field(default_factory=dict)  # k -> encode flushes
    kfps_per_watt: float = 0.0
    mean_frame_uj: float = 0.0
    dense_kfps_per_watt: float = 0.0
    mean_bits: float = 0.0       # mean planned weight width (8.0 = uniform
    #                              int8; < 8 under a mixed-precision plan)
    flush_wall_ms: dict = field(default_factory=dict)  # bucket -> mean
    #                              *measured* host ms per flush (only
    #                              populated when the server timed flushes,
    #                              i.e. under --autotune) — the observed
    #                              counterpart of the modeled latency
    recalibrations: int = 0      # drift-triggered MR re-tunes billed to
    #                              this stream (0 unless the server runs a
    #                              NoiseSpec with recal_bound_nm > 0)
    predictions: dict = field(default_factory=dict)   # frame_idx -> class
    poisoned: bool = False       # session terminated early by an
    #                              unrecoverable fault — predictions cover
    #                              only the frames flushed before it died
    failure: str = ""            # why (empty for a clean stream)
    retries: int = 0             # transient-fault flush retries this
    #                              stream's frames rode through
    shed_frames: int = 0         # frames dropped by ingest load shedding
    #                              (never gated, encoded or predicted)

    @property
    def fps(self) -> float:
        return self.frames / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def energy_saved(self) -> float:
        if self.dense_kfps_per_watt <= 0 or self.kfps_per_watt <= 0:
            return 0.0
        return 1.0 - self.dense_kfps_per_watt / self.kfps_per_watt

    def summary(self) -> str:
        hist = " ".join(f"k={k}:{v}" for k, v in self.bucket_hits.items())
        return (f"{self.frames} frames in {self.wall_s:.2f}s -> "
                f"{self.fps:.1f} frames/s | model {self.kfps_per_watt:.1f} "
                f"KFPS/W ({self.mean_frame_uj:.2f} uJ/frame, "
                f"{self.energy_saved:+.1%} vs dense) | mgnet scored "
                f"{self.scored_frames}/{self.frames} | buckets: {hist}")


class StreamSession:
    """One stream's serving state, multiplexed by ``StreamServer``.

    A session is *passive*: the server pulls its next ingest chunk, gates it
    through the session's own mask cache, routes/encodes on the shared jit
    ladder, and records flush outcomes back here. Per-stream numbers
    (accounting, histogram, predictions) therefore aggregate exactly as a
    solo run of the same stream would — interleaving sessions changes only
    *when* launches happen, never what each stream computes.
    """

    def __init__(self, sid: int, stream: VideoStream, n_frames: int,
                 start: int, serve_cfg: ServingConfig, cfg,
                 ladder: BucketLadder | None = None,
                 layer_bits: tuple | None = None):
        self.sid = sid
        self.stream = stream
        self.n_frames = n_frames
        self.start = start
        self.limit = start + n_frames
        self.serve_cfg = serve_cfg
        self.cache = TemporalMaskCache(serve_cfg.mask_refresh,
                                       serve_cfg.delta_threshold)
        self.layer_bits = (tuple(int(b) for b in layer_bits)
                           if layer_bits is not None else None)
        self.acct = StreamAccounting(
            cfg, ladder_sizes=ladder.sizes if ladder is not None else None,
            layer_bits=self.layer_bits)
        self.hist = BucketHistogram(ladder) if ladder is not None else None
        self.deferred: list = []     # (frame_idx list, argmax device array)
        self.frames_seen = 0         # valid frames ingested so far
        self.chunks_done = 0         # ingest chunks consumed (the resume
        #                              cursor: a restored session re-opens
        #                              its stream here, not at frame 0)
        self.ingest_done = False
        self.drained = False
        self.finished = False
        self.failed_reason = ""      # non-empty: quarantined by a fault
        self.retries = 0             # transient-fault retries billed here
        self.ingest_attempts = 0     # consecutive ingest-fault retries on
        #                              the *current* chunk (resets on success)
        self.shed_frames = 0         # frames dropped under overload
        self._pending_restore: list | None = None  # queued micro-batch
        #                              rows carried by a checkpoint, pushed
        #                              back by the server at serve() start
        self._it = None

    # -- ingest ------------------------------------------------------------

    def open(self) -> None:
        """Build the chunked, double-buffered ingest iterator.

        Each yielded batch carries both views of the frames: ``frames`` is
        the (possibly still in-flight) device copy the embed/encode jits
        consume, ``frames_host`` the sensor-side numpy the gating walk
        reads — one H2D per chunk, no D2H ever. Ingest stays in full
        ``chunk``-sized transfers (every device shape static); when
        ``n_frames`` is not a chunk multiple, the trailing frames of the
        last chunk are gated but never routed, encoded, predicted or
        accounted (the ``valid`` mask the server applies).

        Resume-aware: a session restored from a checkpoint re-opens at
        ``chunks_done`` chunks past ``start`` — the stream is pure in
        (seed, frame index), so the continuation's frames are exactly the
        ones the interrupted run never consumed.
        """
        sc = self.serve_cfg
        total = (self.n_frames + sc.chunk - 1) // sc.chunk
        self._chunks_left = total - self.chunks_done
        it = self.stream.chunks(sc.chunk,
                                self.start + self.chunks_done * sc.chunk)
        gen = (next(it) for _ in range(self._chunks_left))
        self._it = prefetch_to_device(gen, depth=sc.prefetch_depth,
                                      keys=("frames",))

    def next_batch(self) -> dict | None:
        """Next ingest chunk, or None once the stream's frame budget is
        consumed (``ingest_done`` flips on the *last* chunk, so the server
        drains this session's queues in the same scheduling round)."""
        if self._it is None:
            self.open()
        if self._chunks_left == 0:
            self.ingest_done = True
            return None
        batch = next(self._it)
        self._chunks_left -= 1
        self.chunks_done += 1
        if self._chunks_left == 0:
            self.ingest_done = True
        return batch

    # -- failure / overload (written by the server) ------------------------

    def fail(self, reason: str) -> None:
        """Quarantine: no further ingest, no further flushes; already-
        deferred predictions survive into the poisoned StreamResult."""
        self.failed_reason = reason
        self.ingest_done = True
        self.drained = True

    def shed(self, n: int) -> None:
        """Bill ``n`` load-shed frames (pulled off the sensor but dropped
        before gating — the overload response that keeps the queue bound)."""
        self.shed_frames += n

    # -- per-flush bookkeeping (written by the server) ---------------------

    def record_route(self, bucket: int, n: int) -> None:
        if self.hist is not None:
            self.hist.add(bucket, n)

    def record_flush(self, bucket: int, n_real: int) -> None:
        self.acct.add_encode(bucket, n_real)

    def add_deferred(self, frame_idx: list, preds) -> None:
        self.deferred.append((frame_idx, preds))

    # -- end of stream -----------------------------------------------------

    def finish(self, wall_s: float) -> StreamResult:
        """Materialize deferred predictions and assemble the StreamResult
        (identical field-for-field to the single-stream engine's)."""
        res = StreamResult()
        for fidx, preds in self.deferred:
            for fi, p in zip(fidx, np.asarray(preds)):
                if int(fi) < self.limit:
                    res.predictions[int(fi)] = int(p)
        res.wall_s = wall_s
        res.frames = self.acct.frames
        res.scored_frames = self.cache.scored_frames
        res.reused_frames = self.cache.reused_frames
        res.bucket_hits = (self.hist.as_dict() if self.hist is not None
                           else dict(self.acct.bucket_frames))
        res.bucket_launches = dict(self.acct.bucket_launches)
        res.flush_wall_ms = {
            int(k): self.acct.measured_flush_s(k) * 1e3
            for k in self.acct.flush_wall_n if self.acct.flush_wall_n[k]}
        res.kfps_per_watt = self.acct.kfps_per_watt
        res.mean_frame_uj = self.acct.mean_frame.total_uj
        res.dense_kfps_per_watt = self.acct.dense_baseline_kfps_per_watt()
        res.mean_bits = (sum(self.layer_bits) / len(self.layer_bits)
                         if self.layer_bits else 8.0)
        res.recalibrations = self.acct.recal_events
        res.poisoned = bool(self.failed_reason)
        res.failure = self.failed_reason
        res.retries = self.retries
        res.shed_frames = self.shed_frames
        self.finished = True
        return res

    # -- checkpoint / migration --------------------------------------------

    def state_dict(self) -> tuple[dict, dict]:
        """Snapshot everything needed to resume this stream bitwise:
        the ingest cursor, the mask cache's reference frame/scores, the
        accumulated accounting and histogram, and the deferred (not yet
        materialized) predictions. Returns ``(arrays, meta)`` — numpy
        leaves separate from the JSON-able descriptor, the split
        ``repro.checkpoint`` stores natively. The server adds the queued
        micro-batch rows under ``meta["pending"]`` (they live in the
        shared batcher, not here)."""
        arrays: dict = {}
        cs = self.cache.state_dict()
        if cs["ref_frame"] is not None:
            arrays["cache_ref_frame"] = cs["ref_frame"]
            arrays["cache_ref_scores"] = cs["ref_scores"]
        didx: list = []
        dpred: list = []
        for fidx, preds in self.deferred:
            didx.extend(int(i) for i in fidx)
            dpred.append(np.asarray(preds))
        arrays["deferred_idx"] = np.asarray(didx, np.int64)
        arrays["deferred_pred"] = (np.concatenate(dpred) if dpred
                                   else np.zeros(0, np.int32))
        meta = {
            "sid": self.sid, "n_frames": self.n_frames, "start": self.start,
            "chunks_done": self.chunks_done,
            "frames_seen": self.frames_seen,
            "ingest_done": bool(self.ingest_done),
            "drained": bool(self.drained),
            "failed_reason": self.failed_reason,
            "retries": self.retries, "shed_frames": self.shed_frames,
            "cache": {"ref_idx": cs["ref_idx"],
                      "scored_frames": cs["scored_frames"],
                      "reused_frames": cs["reused_frames"]},
            "acct": self.acct.state_dict(),
            "hist": ({str(k): v for k, v in self.hist.as_dict().items()}
                     if self.hist is not None else None),
            "stream": (asdict(self.stream) if is_dataclass(self.stream)
                       else None),
            "pending": [],
        }
        return arrays, meta

    @classmethod
    def from_state(cls, arrays: dict, meta: dict, serve_cfg: ServingConfig,
                   cfg, ladder: BucketLadder | None = None,
                   layer_bits: tuple | None = None,
                   stream: VideoStream | None = None) -> "StreamSession":
        """Rebuild a session from ``state_dict()`` output. ``stream``
        overrides the snapshot's serialized spec — required when the
        original source was not a plain ``VideoStream`` dataclass."""
        if stream is None:
            if meta.get("stream") is None:
                raise ValueError(
                    f"session {meta['sid']}'s snapshot carries no stream "
                    f"spec (non-dataclass source) — pass its stream via "
                    f"``streams={{sid: stream}}``")
            stream = VideoStream(**meta["stream"])
        s = cls(int(meta["sid"]), stream, int(meta["n_frames"]),
                int(meta["start"]), serve_cfg, cfg, ladder=ladder,
                layer_bits=layer_bits)
        s.chunks_done = int(meta["chunks_done"])
        s.frames_seen = int(meta["frames_seen"])
        s.ingest_done = bool(meta["ingest_done"])
        s.drained = bool(meta["drained"])
        s.failed_reason = meta["failed_reason"]
        s.retries = int(meta["retries"])
        s.shed_frames = int(meta["shed_frames"])
        cm = meta["cache"]
        s.cache.load_state({
            "ref_frame": arrays.get("cache_ref_frame"),
            "ref_scores": arrays.get("cache_ref_scores"),
            "ref_idx": cm["ref_idx"],
            "scored_frames": cm["scored_frames"],
            "reused_frames": cm["reused_frames"]})
        s.acct.load_state(meta["acct"])
        if s.hist is not None and meta.get("hist"):
            for k, v in meta["hist"].items():
                s.hist.add(int(k), int(v))
        didx = arrays["deferred_idx"]
        if len(didx):
            s.deferred.append(([int(i) for i in didx],
                               np.asarray(arrays["deferred_pred"])))
        pend = []
        for j, p in enumerate(meta.get("pending", ())):
            toks = p.get("tokens")
            if toks is None:
                toks = arrays[f"pend{j}"]
            pend.append((int(p["bucket"]), np.asarray(toks),
                         [int(f) for f in p["fidx"]], int(p["now"]),
                         bool(p["is_row"])))
        s._pending_restore = pend
        return s
